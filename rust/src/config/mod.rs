//! Typed configuration for experiments, engines, workloads and policies.
//!
//! Every figure bench and example builds an [`ExperimentConfig`], either
//! from presets ([`EngineProfile::a40_llama8b`] / [`EngineProfile::h800_qwen32b`])
//! or from a JSON file ([`ExperimentConfig::from_json`]); `sagesched --config`
//! accepts the same schema.

use crate::slo::{SloClass, SloConfig};
use crate::util::json::Json;

/// Which scheduling policy drives the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// First-come-first-serve (vLLM / SGLang default).
    Fcfs,
    /// FastServe: multi-level feedback queue with quantum demotion.
    FastServe,
    /// SSJF: shortest-job-first on a point output-length prediction.
    Ssjf,
    /// Learning-to-rank: SJF on predicted relative rank.
    Ltr,
    /// TRAIL: SRPT on an iteration-refreshed point prediction.
    Trail,
    /// Mean-of-cost-distribution ordering (fig11 baseline).
    MeanCost,
    /// Gittins index without runtime refresh (fig11 baseline).
    GittinsStatic,
    /// Full SageSched: Gittins index + bucketed runtime refresh.
    SageSched,
    /// Oracle SRPT on true remaining cost (upper bound; not in the paper's
    /// main figures but used by ablation benches).
    OracleSrpt,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 9] = [
        PolicyKind::Fcfs,
        PolicyKind::FastServe,
        PolicyKind::Ssjf,
        PolicyKind::Ltr,
        PolicyKind::Trail,
        PolicyKind::MeanCost,
        PolicyKind::GittinsStatic,
        PolicyKind::SageSched,
        PolicyKind::OracleSrpt,
    ];

    /// The six schedulers compared in the paper's end-to-end figures.
    pub const PAPER_BASELINES: [PolicyKind; 6] = [
        PolicyKind::Fcfs,
        PolicyKind::FastServe,
        PolicyKind::Ssjf,
        PolicyKind::Ltr,
        PolicyKind::Trail,
        PolicyKind::SageSched,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Fcfs => "fcfs",
            PolicyKind::FastServe => "fastserve",
            PolicyKind::Ssjf => "ssjf",
            PolicyKind::Ltr => "ltr",
            PolicyKind::Trail => "trail",
            PolicyKind::MeanCost => "mean",
            PolicyKind::GittinsStatic => "gittins",
            PolicyKind::SageSched => "sagesched",
            PolicyKind::OracleSrpt => "oracle-srpt",
        }
    }

    pub fn from_name(s: &str) -> Option<PolicyKind> {
        PolicyKind::ALL.iter().copied().find(|p| p.name() == s)
    }
}

/// Which output-length predictor feeds the scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PredictorKind {
    /// The paper's semantic-aware history-based predictor (§3.1).
    History,
    /// Semantic-*unaware* history predictor: match on input length only
    /// (fig9 baseline).
    LengthHistory,
    /// "LLM-based" proxy (DistillBert-style) distribution head (fig9).
    Proxy,
    /// Ground-truth oracle distribution.
    Oracle,
    /// Online pairwise learning-to-rank over prompt features with
    /// exponential staleness decay (vllm-ltr style, drift-adaptive).
    Ranking,
}

impl PredictorKind {
    pub fn name(&self) -> &'static str {
        match self {
            PredictorKind::History => "history",
            PredictorKind::LengthHistory => "length-history",
            PredictorKind::Proxy => "proxy",
            PredictorKind::Oracle => "oracle",
            PredictorKind::Ranking => "ranking",
        }
    }

    pub fn from_name(s: &str) -> Option<PredictorKind> {
        [
            PredictorKind::History,
            PredictorKind::LengthHistory,
            PredictorKind::Proxy,
            PredictorKind::Oracle,
            PredictorKind::Ranking,
        ]
        .into_iter()
        .find(|p| p.name() == s)
    }
}

/// Which service-cost model maps lengths to costs (§3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CostModelKind {
    /// The paper's resource-bound model: C = O²/2 + I·O.
    ResourceBound,
    /// C = O (SSJF / TRAIL's implicit model; fig10 baseline).
    OutputLen,
    /// C = I + 2·O (weighted overall length as in Sheng et al.; fig10).
    OverallLen,
}

impl CostModelKind {
    pub fn name(&self) -> &'static str {
        match self {
            CostModelKind::ResourceBound => "resource-bound",
            CostModelKind::OutputLen => "output-len",
            CostModelKind::OverallLen => "overall-len",
        }
    }

    pub fn from_name(s: &str) -> Option<CostModelKind> {
        [
            CostModelKind::ResourceBound,
            CostModelKind::OutputLen,
            CostModelKind::OverallLen,
        ]
        .into_iter()
        .find(|c| c.name() == s)
    }
}

/// The three evaluation datasets (synthetic equivalents; see DESIGN.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// ShareGPT: conversational, mid input / wide mid output.
    ShareGpt,
    /// Alpaca-PubMed summarization: long input / short output.
    Alpaca,
    /// Document-Write: short input / long output.
    Write,
}

impl DatasetKind {
    pub const ALL: [DatasetKind; 3] =
        [DatasetKind::ShareGpt, DatasetKind::Alpaca, DatasetKind::Write];

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::ShareGpt => "sharegpt",
            DatasetKind::Alpaca => "alpaca",
            DatasetKind::Write => "write",
        }
    }

    pub fn from_name(s: &str) -> Option<DatasetKind> {
        DatasetKind::ALL.iter().copied().find(|d| d.name() == s)
    }
}

/// Which arrival process paces the workload's request stream
/// (see [`crate::workload::arrivals`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArrivalKind {
    /// Homogeneous Poisson at the configured `rps` (the default).
    Poisson,
    /// Markov-modulated Poisson: an on/off burst process whose ON-state
    /// rate is `burst_factor`× the OFF-state rate, normalized so the
    /// long-run mean rate stays at the configured `rps`.
    Mmpp,
    /// Diurnal: inhomogeneous Poisson whose rate swings sinusoidally
    /// around `rps` with the configured period and relative amplitude.
    Diurnal,
}

impl ArrivalKind {
    pub const ALL: [ArrivalKind; 3] =
        [ArrivalKind::Poisson, ArrivalKind::Mmpp, ArrivalKind::Diurnal];

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Mmpp => "mmpp",
            ArrivalKind::Diurnal => "diurnal",
        }
    }

    pub fn from_name(s: &str) -> Option<ArrivalKind> {
        ArrivalKind::ALL.iter().copied().find(|a| a.name() == s)
    }
}

/// Arrival-process shape. The `rps` in [`WorkloadConfig`] is always the
/// *long-run mean* rate, so traces generated under different kinds are
/// load-comparable; the kind only redistributes the arrivals in time.
#[derive(Clone, Debug)]
pub struct ArrivalConfig {
    pub kind: ArrivalKind,
    /// MMPP: ON-state rate as a multiple of the OFF-state rate (>= 1).
    pub burst_factor: f64,
    /// MMPP: mean duration of the bursty ON state (seconds).
    pub burst_on_mean: f64,
    /// MMPP: mean duration of the quiet OFF state (seconds).
    pub burst_off_mean: f64,
    /// Diurnal: period of one rate cycle (seconds).
    pub diurnal_period: f64,
    /// Diurnal: relative rate amplitude in [0, 1) — rate swings between
    /// `rps*(1-a)` and `rps*(1+a)`.
    pub diurnal_amplitude: f64,
}

impl Default for ArrivalConfig {
    fn default() -> Self {
        ArrivalConfig {
            kind: ArrivalKind::Poisson,
            burst_factor: 6.0,
            burst_on_mean: 10.0,
            burst_off_mean: 40.0,
            diurnal_period: 120.0,
            diurnal_amplitude: 0.8,
        }
    }
}

impl ArrivalConfig {
    /// Parameter bounds shared by every config surface (JSON and CLI): one
    /// validator so accepted ranges cannot drift between entry points.
    pub fn validate(&self) -> Result<(), String> {
        if self.burst_factor < 1.0
            || self.burst_on_mean <= 0.0
            || self.burst_off_mean <= 0.0
            || self.diurnal_period <= 0.0
            || !(0.0..1.0).contains(&self.diurnal_amplitude)
        {
            return Err("arrival: burst_factor >= 1, state durations and \
                        period > 0, amplitude in [0,1) required"
                .to_string());
        }
        Ok(())
    }
}

/// Which request router fronts the multi-replica cluster
/// (see [`crate::cluster`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RouterKind {
    /// Cycle through replicas in submission order.
    RoundRobin,
    /// Fewest live (queued + running + preempted) requests.
    LeastLoaded,
    /// Lowest KV-block occupancy fraction.
    LeastKv,
    /// Smallest predicted outstanding cost, using the *mean* of the shared
    /// predictor's length distribution under the configured cost model,
    /// normalized by replica speed.
    CostAware,
    /// Like `CostAware` but on a configurable *quantile*
    /// ([`ClusterConfig::router_quantile`]) of each replica's outstanding
    /// predicted-cost distribution instead of its mean — the
    /// distribution-aware router: replicas holding heavy-tailed work repel
    /// traffic even when their mean backlog looks ordinary.
    QuantileCost,
    /// Session stickiness vs load balance: each replica's predicted-cost
    /// backlog is credited with the prefill cost its warm prefix cache
    /// would save this request (probed through the shared-prefix KV
    /// index), so a session's turns keep landing where their history is
    /// warm — until the imbalance outweighs the recompute the cold
    /// replica would pay.
    CacheAffinity,
}

impl RouterKind {
    pub const ALL: [RouterKind; 6] = [
        RouterKind::RoundRobin,
        RouterKind::LeastLoaded,
        RouterKind::LeastKv,
        RouterKind::CostAware,
        RouterKind::QuantileCost,
        RouterKind::CacheAffinity,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round-robin",
            RouterKind::LeastLoaded => "least-loaded",
            RouterKind::LeastKv => "least-kv",
            RouterKind::CostAware => "cost-aware",
            RouterKind::QuantileCost => "quantile-cost",
            RouterKind::CacheAffinity => "cache-affinity",
        }
    }

    pub fn from_name(s: &str) -> Option<RouterKind> {
        RouterKind::ALL.iter().copied().find(|r| r.name() == s)
    }
}

/// One scheduled replica outage for the event-driven cluster simulation:
/// replica `replica` goes down at virtual time `at` (its in-flight requests
/// are re-dispatched through the router over the surviving replicas) and
/// recovers, empty, at `at + duration`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureEvent {
    /// Replica index to fail.
    pub replica: usize,
    /// Virtual time of the failure (seconds).
    pub at: f64,
    /// Downtime before the replica rejoins the routable set (seconds).
    pub duration: f64,
}

impl FailureEvent {
    /// Time bounds shared by every surface that accepts outages (grammar
    /// parser, JSON config, and the cluster's event expansion). NaN is
    /// rejected explicitly — it slips through ordered comparisons and would
    /// panic later inside the event-stream sort.
    pub fn validate(&self) -> Result<(), String> {
        let bad_time = self.at.is_nan() || self.duration.is_nan();
        if bad_time || self.at < 0.0 || self.duration <= 0.0 {
            return Err(format!(
                "failure event for replica {}: need at >= 0 and duration > 0",
                self.replica
            ));
        }
        Ok(())
    }

    /// Parse a comma-separated `replica@start+duration` list — the CLI's
    /// `--fail` grammar, e.g. `1@30+10,0@60+5` (replica 1 down from t=30
    /// for 10 s, replica 0 down from t=60 for 5 s). Shared by the
    /// `sagesched` binary and the examples so the grammar cannot diverge.
    pub fn parse_list(s: &str) -> Result<Vec<FailureEvent>, String> {
        s.split(',')
            .map(|item| {
                let item = item.trim();
                let shape =
                    || format!("failure {item:?}: expected replica@start+duration");
                let (rep, rest) = item.split_once('@').ok_or_else(shape)?;
                let (at, dur) = rest.split_once('+').ok_or_else(shape)?;
                let ev = FailureEvent {
                    replica: rep
                        .trim()
                        .parse()
                        .map_err(|_| format!("failure {item:?}: bad replica index"))?,
                    at: at
                        .trim()
                        .parse()
                        .map_err(|_| format!("failure {item:?}: bad start time"))?,
                    duration: dur
                        .trim()
                        .parse()
                        .map_err(|_| format!("failure {item:?}: bad duration"))?,
                };
                ev.validate().map_err(|e| format!("{e} (in {item:?})"))?;
                Ok(ev)
            })
            .collect()
    }
}

/// One correlated failure domain for the event-driven cluster: a named
/// group of replicas (a rack, a power zone, a network segment) that fails
/// *together* when a [`DomainFailureEvent`] targets it. Replicas may be
/// referenced before they exist when autoscaling is on (membership is by
/// index, and autoscaled indices are deterministic); existence is checked
/// at the instant the outage fires.
#[derive(Clone, Debug, PartialEq)]
pub struct FailureDomain {
    /// Label for reports and error messages (e.g. "rack0").
    pub name: String,
    /// Member replica indices.
    pub replicas: Vec<usize>,
}

impl FailureDomain {
    /// Parse a semicolon-separated domain list — the CLI's `--domains`
    /// grammar, e.g. `rack0:0,1;rack1:2,3` (two domains of two replicas
    /// each). The `name:` prefix is optional; unnamed groups are labeled
    /// `domain<k>` by position.
    pub fn parse_groups(s: &str) -> Result<Vec<FailureDomain>, String> {
        s.split(';')
            .enumerate()
            .map(|(k, group)| {
                let group = group.trim();
                let (name, members) = match group.split_once(':') {
                    Some((n, rest)) => (n.trim().to_string(), rest),
                    None => (format!("domain{k}"), group),
                };
                let replicas: Result<Vec<usize>, String> = members
                    .split(',')
                    .map(|r| {
                        r.trim().parse::<usize>().map_err(|_| {
                            format!("domain {group:?}: bad replica index {r:?}")
                        })
                    })
                    .collect();
                let replicas = replicas?;
                if replicas.is_empty() {
                    return Err(format!("domain {group:?}: no replicas"));
                }
                Ok(FailureDomain { name, replicas })
            })
            .collect()
    }
}

/// One scheduled failure-domain outage: every member of domain `domain`
/// goes down at virtual time `at` — in a single event, so the pooled
/// re-dispatch storm routes over the true survivor set — and all members
/// recover, empty, at `at + duration`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DomainFailureEvent {
    /// Index into [`ClusterConfig::failure_domains`].
    pub domain: usize,
    /// Virtual time of the outage (seconds).
    pub at: f64,
    /// Downtime before the members rejoin the routable set (seconds).
    pub duration: f64,
}

impl DomainFailureEvent {
    /// Same time bounds as [`FailureEvent::validate`]; NaN is rejected
    /// explicitly because it slips through ordered comparisons.
    pub fn validate(&self) -> Result<(), String> {
        let bad_time = self.at.is_nan() || self.duration.is_nan();
        if bad_time || self.at < 0.0 || self.duration <= 0.0 {
            return Err(format!(
                "domain failure event for domain {}: need at >= 0 and duration > 0",
                self.domain
            ));
        }
        Ok(())
    }

    /// Parse a comma-separated `domain@start+duration` list — the CLI's
    /// `--fail-domain` grammar, e.g. `0@30+10` (domain 0 down from t=30
    /// for 10 s). Mirrors [`FailureEvent::parse_list`].
    pub fn parse_list(s: &str) -> Result<Vec<DomainFailureEvent>, String> {
        s.split(',')
            .map(|item| {
                let item = item.trim();
                let shape =
                    || format!("domain failure {item:?}: expected domain@start+duration");
                let (dom, rest) = item.split_once('@').ok_or_else(shape)?;
                let (at, dur) = rest.split_once('+').ok_or_else(shape)?;
                let ev = DomainFailureEvent {
                    domain: dom
                        .trim()
                        .parse()
                        .map_err(|_| format!("domain failure {item:?}: bad domain index"))?,
                    at: at
                        .trim()
                        .parse()
                        .map_err(|_| format!("domain failure {item:?}: bad start time"))?,
                    duration: dur
                        .trim()
                        .parse()
                        .map_err(|_| format!("domain failure {item:?}: bad duration"))?,
                };
                ev.validate().map_err(|e| format!("{e} (in {item:?})"))?;
                Ok(ev)
            })
            .collect()
    }
}

/// Which autoscaling policy drives elastic replica scale-out/in
/// (see [`crate::autoscale`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AutoscaleKind {
    /// No autoscaling: the replica count is fixed at t=0 (the default).
    Off,
    /// Scripted add/remove at fixed times (the deterministic test anchor).
    Step,
    /// Scale on backlog / KV-occupancy watermarks with cooldown +
    /// hysteresis.
    Reactive,
    /// Provision for a configurable quantile of the forecast outstanding
    /// service-cost distribution (summed per-request predictor
    /// distributions through the cost model).
    UncertaintyAware,
}

impl AutoscaleKind {
    pub const ALL: [AutoscaleKind; 4] = [
        AutoscaleKind::Off,
        AutoscaleKind::Step,
        AutoscaleKind::Reactive,
        AutoscaleKind::UncertaintyAware,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            AutoscaleKind::Off => "off",
            AutoscaleKind::Step => "step",
            AutoscaleKind::Reactive => "reactive",
            AutoscaleKind::UncertaintyAware => "uncertainty",
        }
    }

    pub fn from_name(s: &str) -> Option<AutoscaleKind> {
        AutoscaleKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// One scripted autoscaling step: at virtual time `at`, set the desired
/// replica count to `target` (the cluster adds or drains replicas to meet
/// it, subject to the provisioning delay).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScaleStep {
    /// Virtual time of the step (seconds).
    pub at: f64,
    /// Desired replica count from this time on.
    pub target: usize,
}

impl ScaleStep {
    /// NaN is rejected explicitly — it slips through ordered comparisons
    /// and would panic later inside the step-schedule sort.
    pub fn validate(&self) -> Result<(), String> {
        if self.at.is_nan() || self.at < 0.0 || self.target == 0 {
            return Err(format!(
                "scale step at {}: need at >= 0 and target >= 1",
                self.at
            ));
        }
        Ok(())
    }

    /// Parse a comma-separated `time@target` list — the CLI's
    /// `--scale-steps` grammar, e.g. `10@6,40@2` (at t=10 s grow the fleet
    /// to 6 replicas, at t=40 s shrink it to 2). Shared by the `sagesched`
    /// binary and the examples so the grammar cannot diverge.
    pub fn parse_list(s: &str) -> Result<Vec<ScaleStep>, String> {
        s.split(',')
            .map(|item| {
                let item = item.trim();
                let shape = || format!("scale step {item:?}: expected time@target");
                let (at, target) = item.split_once('@').ok_or_else(shape)?;
                let ev = ScaleStep {
                    at: at
                        .trim()
                        .parse()
                        .map_err(|_| format!("scale step {item:?}: bad time"))?,
                    target: target
                        .trim()
                        .parse()
                        .map_err(|_| format!("scale step {item:?}: bad target"))?,
                };
                ev.validate().map_err(|e| format!("{e} (in {item:?})"))?;
                Ok(ev)
            })
            .collect()
    }
}

/// Elastic autoscaling shape for the event-driven cluster (see
/// [`crate::autoscale`] for the policy semantics).
#[derive(Clone, Debug)]
pub struct AutoscaleConfig {
    /// Which policy decides the desired replica count.
    pub kind: AutoscaleKind,
    /// Scripted steps (required non-empty for [`AutoscaleKind::Step`]).
    pub steps: Vec<ScaleStep>,
    /// Floor on the desired replica count (reactive / uncertainty).
    pub min_replicas: usize,
    /// Cap on the desired replica count (reactive / uncertainty) — the
    /// "peak provisioning" a static fleet would be compared at.
    pub max_replicas: usize,
    /// Seconds between a scale-out decision and the new replica joining
    /// the routable set (cold-start / provisioning time).
    pub provision_delay: f64,
    /// Minimum seconds between two scaling actions (reactive /
    /// uncertainty; scripted steps ignore it).
    pub cooldown: f64,
    /// Seconds between autoscaler decision points.
    pub interval: f64,
    /// Reactive: scale out when live requests per active replica exceed
    /// this watermark.
    pub high_watermark: f64,
    /// Reactive: scale in when live requests per active replica fall below
    /// this watermark (must be < `high_watermark`: the gap is the
    /// hysteresis band).
    pub low_watermark: f64,
    /// Reactive: scale out when mean KV occupancy exceeds this fraction.
    pub kv_high_watermark: f64,
    /// Reactive: scale in only while mean KV occupancy is below this.
    pub kv_low_watermark: f64,
    /// Uncertainty-aware: provision for this quantile of the forecast
    /// outstanding service-cost distribution (e.g. 0.9 = p90).
    pub quantile: f64,
    /// Uncertainty-aware: outstanding service cost (cost-model units) one
    /// replica is provisioned to carry.
    pub work_per_replica: f64,
    /// Pre-warm a freshly provisioned replica's local predictor with the
    /// offline corpus (`history_prewarm`); false models a fully cold start.
    pub prewarm: bool,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            kind: AutoscaleKind::Off,
            steps: Vec::new(),
            min_replicas: 1,
            max_replicas: 16,
            provision_delay: 2.0,
            cooldown: 5.0,
            interval: 1.0,
            high_watermark: 8.0,
            low_watermark: 2.0,
            kv_high_watermark: 0.85,
            kv_low_watermark: 0.30,
            quantile: 0.9,
            work_per_replica: 1.0e6,
            prewarm: false,
        }
    }
}

impl AutoscaleConfig {
    /// Parameter bounds shared by every config surface (JSON and CLI).
    pub fn validate(&self) -> Result<(), String> {
        let numeric = [
            self.provision_delay,
            self.cooldown,
            self.interval,
            self.high_watermark,
            self.low_watermark,
            self.kv_high_watermark,
            self.kv_low_watermark,
            self.quantile,
            self.work_per_replica,
        ];
        if numeric.iter().any(|v| v.is_nan()) {
            return Err("autoscale: NaN parameter".to_string());
        }
        if self.kind == AutoscaleKind::Step && self.steps.is_empty() {
            return Err("autoscale: step schedule needs at least one \
                        time@target step"
                .to_string());
        }
        for s in &self.steps {
            s.validate().map_err(|e| format!("autoscale: {e}"))?;
        }
        if self.min_replicas == 0 || self.max_replicas < self.min_replicas {
            return Err("autoscale: need 1 <= min_replicas <= max_replicas"
                .to_string());
        }
        if self.provision_delay < 0.0 || self.cooldown < 0.0 || self.interval <= 0.0 {
            return Err("autoscale: provision_delay/cooldown >= 0 and \
                        interval > 0 required"
                .to_string());
        }
        if self.low_watermark < 0.0 || self.high_watermark <= self.low_watermark {
            return Err("autoscale: need 0 <= low_watermark < high_watermark"
                .to_string());
        }
        if !(0.0..=1.0).contains(&self.kv_low_watermark)
            || !(0.0..=1.0).contains(&self.kv_high_watermark)
            || self.kv_high_watermark <= self.kv_low_watermark
        {
            return Err("autoscale: KV watermarks must satisfy \
                        0 <= low < high <= 1"
                .to_string());
        }
        if !(0.0 < self.quantile && self.quantile < 1.0) || self.work_per_replica <= 0.0 {
            return Err("autoscale: quantile in (0,1) and work_per_replica > 0 \
                        required"
                .to_string());
        }
        Ok(())
    }
}

/// Pool role of one replica under disaggregated prefill/decode serving.
///
/// Prefill replicas run prompts to first token and hand the request off
/// through the KV-transfer fabric; decode replicas receive the handoff and
/// run the remaining decode. With [`ClusterConfig::pools`] empty the
/// cluster is *colocated* — every replica serves both phases — and no role
/// is assigned.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PoolRole {
    /// Compute-bound pool: runs prompts to first token only.
    Prefill,
    /// Memory-bound pool: receives prefilled requests over the fabric and
    /// decodes them to completion.
    Decode,
}

impl PoolRole {
    pub const ALL: [PoolRole; 2] = [PoolRole::Prefill, PoolRole::Decode];

    /// Dense index (0 = prefill, 1 = decode) for per-pool counter arrays.
    pub fn index(&self) -> usize {
        match self {
            PoolRole::Prefill => 0,
            PoolRole::Decode => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PoolRole::Prefill => "prefill",
            PoolRole::Decode => "decode",
        }
    }

    pub fn from_name(s: &str) -> Option<PoolRole> {
        PoolRole::ALL.iter().copied().find(|p| p.name() == s)
    }
}

/// Multi-replica cluster shape for the event-driven cluster simulation.
///
/// The heterogeneity vectors are *cycled* over replica indices (replica `i`
/// uses entry `i % len`), so `speeds: [1.0, 0.5]` over 4 replicas models a
/// fleet of two fast and two slow GPUs. Empty vectors mean "use the base
/// [`EngineProfile`] unchanged". Replicas added by autoscaling continue the
/// cycle at their (new) index.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of serving replicas at t=0 (each a full coordinator + sim
    /// engine; autoscaling may add or retire replicas mid-run).
    pub replicas: usize,
    /// Routing policy at the cluster front door.
    pub router: RouterKind,
    /// Quantile the `quantile-cost` router provisions against (e.g. 0.9).
    pub router_quantile: f64,
    /// Per-replica speed multipliers (2.0 = twice as fast; cycled).
    pub speeds: Vec<f64>,
    /// Per-replica max decode batch overrides (cycled).
    pub batch_sizes: Vec<usize>,
    /// Per-replica KV-capacity (tokens) overrides (cycled).
    pub kv_capacities: Vec<usize>,
    /// Scheduled replica outages (failure + recovery; may be empty).
    pub failures: Vec<FailureEvent>,
    /// Correlated failure domains (rack/zone groups; may be empty).
    /// A [`DomainFailureEvent`] takes every member down in one event.
    pub failure_domains: Vec<FailureDomain>,
    /// Scheduled domain outages (indices into `failure_domains`).
    pub domain_failures: Vec<DomainFailureEvent>,
    /// Elastic autoscaling policy (off by default).
    pub autoscale: AutoscaleConfig,
    /// Work stealing: cost-model units of transfer penalty per prompt
    /// token. Each steal must save more speed-normalized backlog wait than
    /// it costs to ship the prompt; 0 disables the gate (free migration,
    /// the pre-autoscale behavior).
    pub steal_transfer_per_token: f64,
    /// Migration-cost-aware scale-in: cost-model units charged per
    /// resident KV token (prompt + generated prefix) to migrate a
    /// partially-generated request off a scale-in victim. When > 0, victim
    /// selection minimizes predicted drain cost and drains migrate partial
    /// work whose transfer is cheaper than waiting out its predicted
    /// remaining cost; 0 (the default) keeps the legacy drain-only
    /// behavior (only never-scheduled work moves).
    pub migration_kv_per_token: f64,
    /// Quantile of each live request's predicted *remaining* cost used by
    /// migration-cost-aware scale-in (victim scoring and the per-request
    /// migrate-vs-wait decision). Pricing the tail rather than the mean is
    /// what keeps a predicted-long straggler from anchoring a drain.
    pub migration_quantile: f64,
    /// Disaggregated prefill/decode serving: per-replica pool roles,
    /// cycled over replica indices like the heterogeneity vectors. Empty
    /// (the default) is colocated serving — every replica runs both
    /// phases and no KV-transfer fabric exists. Non-empty lists must
    /// yield at least one replica of each role over the initial fleet.
    pub pools: Vec<PoolRole>,
    /// KV-transfer fabric: bandwidth of one link in resident KV tokens
    /// per second. A handoff of a request holding `input_len + generated`
    /// KV tokens occupies a link for `tokens / bandwidth` seconds.
    pub transfer_bandwidth: f64,
    /// KV-transfer fabric: number of parallel links. Handoffs queue on
    /// the earliest-free link, so a burst of prefill completions drains
    /// at `links * bandwidth` aggregate throughput.
    pub transfer_links: usize,
    /// Router for delivering fabric handoffs into the decode pool. `None`
    /// (the default) uses the front-door [`RouterKind`] — but always as a
    /// separate instance, so per-pool router state (round-robin cursors)
    /// never aliases. Ignored in colocated mode.
    pub decode_router: Option<RouterKind>,
    /// Shortlist width of the cache-affinity dispatch fast path: the
    /// per-request score adjustment is applied to the `shortlist_k`
    /// best-base-score replicas (plus every known warm site) and a
    /// dominance bound proves no replica outside the shortlist can win —
    /// falling back to the full rescan when it can't. Larger values trade
    /// per-dispatch work for fewer fallbacks; must be >= 1.
    pub shortlist_k: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 4,
            router: RouterKind::LeastLoaded,
            router_quantile: 0.9,
            speeds: Vec::new(),
            batch_sizes: Vec::new(),
            kv_capacities: Vec::new(),
            failures: Vec::new(),
            failure_domains: Vec::new(),
            domain_failures: Vec::new(),
            autoscale: AutoscaleConfig::default(),
            steal_transfer_per_token: 2.0,
            migration_kv_per_token: 0.0,
            migration_quantile: 0.9,
            pools: Vec::new(),
            transfer_bandwidth: 20_000.0,
            transfer_links: 2,
            decode_router: None,
            shortlist_k: 8,
        }
    }
}

impl ClusterConfig {
    /// Migration, stealing, and disaggregation parameter bounds shared by
    /// every config surface (CLI, JSON, and the cluster's own run-time
    /// validation) — one home, so the valid ranges cannot drift between
    /// surfaces. Out-of-range quantiles are rejected here rather than
    /// flowing silently into `normal_quantile`.
    pub fn validate(&self) -> Result<(), String> {
        if self.migration_kv_per_token < 0.0 || self.migration_kv_per_token.is_nan() {
            return Err("cluster.migration_kv_per_token must be >= 0".to_string());
        }
        if !(0.0 < self.migration_quantile && self.migration_quantile < 1.0) {
            return Err("cluster.migration_quantile must be in (0,1)".to_string());
        }
        if self.steal_transfer_per_token < 0.0 || self.steal_transfer_per_token.is_nan()
        {
            return Err("cluster.steal_transfer_per_token must be >= 0".to_string());
        }
        if !(self.transfer_bandwidth > 0.0 && self.transfer_bandwidth.is_finite()) {
            return Err("cluster.transfer_bandwidth must be finite and > 0".to_string());
        }
        if self.transfer_links == 0 {
            return Err("cluster.transfer_links must be >= 1".to_string());
        }
        if self.shortlist_k == 0 {
            return Err("cluster.shortlist_k must be >= 1".to_string());
        }
        if !self.pools.is_empty() {
            if self.replicas < 2 {
                return Err(
                    "cluster.pools: disaggregation needs at least 2 replicas".to_string()
                );
            }
            for role in PoolRole::ALL {
                if !(0..self.replicas).any(|i| self.pool_of(i) == Some(role)) {
                    return Err(format!(
                        "cluster.pools must yield at least one {} replica \
                         over the initial fleet",
                        role.name()
                    ));
                }
            }
        }
        Ok(())
    }

    /// Whether the cluster runs disaggregated prefill/decode pools.
    pub fn disagg(&self) -> bool {
        !self.pools.is_empty()
    }

    /// Pool role of replica `i` (cycled), `None` under colocated serving.
    pub fn pool_of(&self, i: usize) -> Option<PoolRole> {
        Self::cycled(&self.pools, i)
    }

    fn cycled<T: Copy>(v: &[T], i: usize) -> Option<T> {
        if v.is_empty() {
            None
        } else {
            Some(v[i % v.len()])
        }
    }

    /// Speed multiplier of replica `i`.
    pub fn speed_of(&self, i: usize) -> f64 {
        Self::cycled(&self.speeds, i).unwrap_or(1.0)
    }

    /// Concrete engine profile for replica `i`, derived from `base`.
    pub fn replica_profile(&self, base: &EngineProfile, i: usize) -> EngineProfile {
        let mut p = base.scaled(self.speed_of(i));
        if let Some(b) = Self::cycled(&self.batch_sizes, i) {
            p.max_batch = b;
        }
        if let Some(kv) = Self::cycled(&self.kv_capacities, i) {
            p.kv_capacity = kv;
        }
        p
    }
}

/// How preempted requests give up / regain their KV cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreemptMode {
    /// Drop KV, re-prefill prompt + generated prefix on resume.
    Recompute,
    /// Swap KV to host memory; pay bandwidth cost out and in.
    Swap,
}

/// Simulated GPU/model profile: the roofline step-time model plus memory
/// capacity. See DESIGN.md §Substitutions for the calibration rationale.
#[derive(Clone, Debug)]
pub struct EngineProfile {
    pub name: String,
    /// Max sequences batched per decode step.
    pub max_batch: usize,
    /// KV-cache capacity in tokens.
    pub kv_capacity: usize,
    /// Decode compute term: seconds = c0 + c1 * batch_size.
    pub decode_c0: f64,
    pub decode_c1: f64,
    /// Decode memory term: seconds = m0 + m1 * total_resident_kv_tokens.
    pub decode_m0: f64,
    pub decode_m1: f64,
    /// Prefill: seconds = p0 + p1 * input_len + p2 * input_len².
    pub prefill_p0: f64,
    pub prefill_p1: f64,
    pub prefill_p2: f64,
    /// Swap bandwidth: seconds per KV token moved (out or in).
    pub swap_per_token: f64,
    /// Hard cap on generated tokens (safety against runaway sims).
    pub max_output: u32,
}

impl EngineProfile {
    /// A40-PCIe-48GB serving Llama3.1-8B (paper testbed 1).
    pub fn a40_llama8b() -> EngineProfile {
        EngineProfile {
            name: "a40-llama8b".into(),
            max_batch: 256,
            kv_capacity: 10_000,
            decode_c0: 0.010,
            decode_c1: 5.0e-5,
            decode_m0: 0.002,
            decode_m1: 2.2e-7,
            prefill_p0: 0.004,
            prefill_p1: 2.0e-5,
            prefill_p2: 5.0e-9,
            swap_per_token: 1.0e-6,
            max_output: 4096,
        }
    }

    /// H800-PCIe-96GB serving Qwen3-32B (paper testbed 2): faster per-token
    /// compute, heavier per-token KV, tighter effective capacity.
    pub fn h800_qwen32b() -> EngineProfile {
        EngineProfile {
            name: "h800-qwen32b".into(),
            max_batch: 256,
            kv_capacity: 8_000,
            decode_c0: 0.012,
            decode_c1: 5.0e-5,
            decode_m0: 0.002,
            decode_m1: 2.5e-7,
            prefill_p0: 0.004,
            prefill_p1: 1.6e-5,
            prefill_p2: 4.0e-9,
            swap_per_token: 1.2e-6,
            max_output: 4096,
        }
    }

    /// Derive a profile running at `speed`× this one (all time constants
    /// divided by the multiplier; capacities unchanged). Used for
    /// heterogeneous cluster replicas.
    pub fn scaled(&self, speed: f64) -> EngineProfile {
        assert!(speed > 0.0, "speed multiplier must be positive");
        let mut p = self.clone();
        p.decode_c0 /= speed;
        p.decode_c1 /= speed;
        p.decode_m0 /= speed;
        p.decode_m1 /= speed;
        p.prefill_p0 /= speed;
        p.prefill_p1 /= speed;
        p.prefill_p2 /= speed;
        p.swap_per_token /= speed;
        p
    }

    pub fn by_name(s: &str) -> Option<EngineProfile> {
        match s {
            "a40-llama8b" => Some(EngineProfile::a40_llama8b()),
            "h800-qwen32b" => Some(EngineProfile::h800_qwen32b()),
            _ => None,
        }
    }
}

/// Mid-run workload drift: at a configurable point in the stream the
/// topic → output-length mapping shifts (and optionally the dataset mix),
/// while prompt *content* — embeddings, topic directions — stays fixed.
/// That is the adversarial case for history-based prediction: retrieval
/// keeps finding confident semantic matches whose recorded lengths now
/// describe the wrong regime, so an adaptive predictor must unlearn, not
/// merely fill a cold cache.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftConfig {
    /// Fraction of `n_requests` after which the shift applies; 0 disables
    /// drift entirely (the default — existing seeded traces are unchanged).
    pub at_fraction: f64,
    /// Rotate each dataset's per-topic output-length profiles among its
    /// topics at the drift point (same marginals, remapped semantics).
    pub remap_topics: bool,
    /// Replacement dataset mix after the drift point; empty keeps the mix.
    pub mix: Vec<(DatasetKind, f64)>,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig { at_fraction: 0.0, remap_topics: true, mix: Vec::new() }
    }
}

impl DriftConfig {
    pub fn enabled(&self) -> bool {
        self.at_fraction > 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..1.0).contains(&self.at_fraction) {
            return Err(format!(
                "drift.at_fraction must be in [0,1), got {}",
                self.at_fraction
            ));
        }
        Ok(())
    }
}

/// Multi-turn session traffic (see [`crate::workload`]): instead of
/// independent single-shot requests, a fraction of arrivals *initiate
/// sessions* — users who send a turn, wait out a think time, and come back
/// with the conversation so far as a growing shared prefix. Turns carry an
/// explicit prefix token-key chain on [`crate::core::Request`], which is
/// what the shared-prefix KV cache and the cache-affinity router consume.
/// Session structure is drawn from a dedicated RNG stream: with
/// `enabled: false` (the default) existing seeded traces are byte-identical.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionConfig {
    /// Master switch; off = pure single-shot traffic, exactly as before.
    pub enabled: bool,
    /// Probability an arrival initiates a session rather than a single-shot
    /// request. Higher = more traffic shares prefixes (fig16's x-axis).
    pub prefix_share: f64,
    /// Mean turns per session (geometric).
    pub turns_mean: f64,
    /// Mean user think time between turns, seconds (exponential).
    pub think_mean: f64,
    /// Tokens of the per-dataset shared system prompt every session of a
    /// dataset pool starts from (the cross-session shareable prefix).
    pub system_prompt_tokens: u32,
    /// Distinct system prompts per dataset (sessions draw one uniformly;
    /// fewer pools = more cross-session sharing).
    pub prompts_per_dataset: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            enabled: false,
            prefix_share: 0.6,
            turns_mean: 4.0,
            think_mean: 6.0,
            system_prompt_tokens: 256,
            prompts_per_dataset: 4,
        }
    }
}

impl SessionConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.prefix_share) {
            return Err(format!(
                "sessions.prefix_share must be in [0,1], got {}",
                self.prefix_share
            ));
        }
        if self.turns_mean < 1.0 {
            return Err(format!(
                "sessions.turns_mean must be >= 1, got {}",
                self.turns_mean
            ));
        }
        if self.think_mean <= 0.0 {
            return Err(format!(
                "sessions.think_mean must be > 0, got {}",
                self.think_mean
            ));
        }
        if self.prompts_per_dataset == 0 {
            return Err("sessions.prompts_per_dataset must be >= 1".to_string());
        }
        Ok(())
    }
}

/// Workload shape: dataset mixture, arrival process, size.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// (dataset, weight) mixture; weights need not sum to 1.
    pub mix: Vec<(DatasetKind, f64)>,
    /// (SLO class, weight) mixture the generator stamps requests with;
    /// weights need not sum to 1. Stamping draws from a *dedicated* RNG
    /// stream, so changing the mix never perturbs the arrival/sampling
    /// streams of an existing seeded trace.
    pub slo_mix: Vec<(SloClass, f64)>,
    /// Long-run mean arrival rate, requests per second.
    pub rps: f64,
    /// Arrival-process shape pacing the stream at that mean rate.
    pub arrival: ArrivalConfig,
    /// Number of requests to generate.
    pub n_requests: usize,
    /// Latent topics per dataset (drives prompt-similarity structure).
    pub topics_per_dataset: usize,
    /// Embedding perturbation within a topic (higher = less similar).
    pub embed_sigma: f32,
    /// Embedding dimension.
    pub embed_dim: usize,
    /// Seed for the latent-topic universe. Kept *separate* from the
    /// request-stream seed so that different traces (serving run, pre-warm
    /// corpus, probe sets) sample from the same topic population — as
    /// different days of traffic over one user base would.
    pub topic_seed: u64,
    /// Mid-run request-mix shift (disabled by default).
    pub drift: DriftConfig,
    /// Multi-turn session traffic (disabled by default).
    pub sessions: SessionConfig,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            mix: vec![
                (DatasetKind::ShareGpt, 1.0),
                (DatasetKind::Alpaca, 1.0),
                (DatasetKind::Write, 1.0),
            ],
            slo_mix: vec![
                (SloClass::Interactive, 0.25),
                (SloClass::Standard, 0.5),
                (SloClass::Batch, 0.25),
            ],
            rps: 8.0,
            arrival: ArrivalConfig::default(),
            n_requests: 600,
            topics_per_dataset: 16,
            embed_sigma: 0.05,
            embed_dim: 64,
            topic_seed: 42,
            drift: DriftConfig::default(),
            sessions: SessionConfig::default(),
        }
    }
}

impl WorkloadConfig {
    pub fn single(dataset: DatasetKind) -> WorkloadConfig {
        WorkloadConfig { mix: vec![(dataset, 1.0)], ..WorkloadConfig::default() }
    }
}

/// Everything needed to run one serving experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub seed: u64,
    pub workload: WorkloadConfig,
    pub engine: EngineProfile,
    pub policy: PolicyKind,
    pub predictor: PredictorKind,
    pub cost_model: CostModelKind,
    pub preempt_mode: PreemptMode,
    /// History predictor: cosine-similarity threshold (paper default 0.8).
    pub similarity_threshold: f32,
    /// History predictor: sliding window capacity (paper default 10k).
    pub history_capacity: usize,
    /// Pre-warm the history window with this many offline-profiled
    /// requests before serving (the paper augments the searching set with
    /// public-dataset requests during warm-up; this is that corpus).
    pub history_prewarm: usize,
    /// Gittins refresh bucket size in output tokens (paper default 200).
    pub bucket_tokens: u32,
    /// Max support points kept in predicted distributions.
    pub dist_max_support: usize,
    /// FastServe MLFQ: base quantum in cost units and number of levels.
    pub mlfq_quantum: f64,
    pub mlfq_levels: usize,
    /// Fraction of history-warmup requests run before measurement starts.
    pub warmup_fraction: f64,
    /// Fig. 11 noise injection: mix a uniform distribution into every
    /// predicted distribution at this weight (paper uses 1:4 ⇒ 0.2).
    pub noise_mix: f64,
    /// IO-aware preemption (paper appendix, SageSched aspect (iii)):
    /// relative priority margin a challenger must win by before a running
    /// request is swapped out (0 disables the hysteresis).
    pub preempt_hysteresis: f64,
    /// IO-aware preemption: never swap out a running request predicted to
    /// finish within this many output tokens (swapping it would cost more
    /// IO than letting it drain). 0 disables.
    pub preempt_finish_guard: u32,
    /// Admission control: reject new requests once this many are live
    /// (0 = unbounded; the paper's scalability setup buffers up to 1,000).
    pub max_queue: usize,
    /// Abort queued requests older than this many seconds (0 = never).
    pub request_timeout: f64,
    /// Multi-replica cluster shape (used by `sagesched cluster` and
    /// [`crate::cluster`]'s event-driven simulation).
    pub cluster: ClusterConfig,
    /// Per-request SLO classes: tier targets/weights and the class-aware
    /// scheduling/admission/routing switch (see [`crate::slo`]).
    pub slo: SloConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 0,
            workload: WorkloadConfig::default(),
            engine: EngineProfile::a40_llama8b(),
            policy: PolicyKind::SageSched,
            predictor: PredictorKind::History,
            cost_model: CostModelKind::ResourceBound,
            preempt_mode: PreemptMode::Swap,
            similarity_threshold: 0.8,
            history_capacity: 10_000,
            history_prewarm: 4_000,
            bucket_tokens: 200,
            dist_max_support: 64,
            mlfq_quantum: 32.0,
            mlfq_levels: 6,
            warmup_fraction: 0.15,
            noise_mix: 0.0,
            preempt_hysteresis: 0.10,
            preempt_finish_guard: 16,
            max_queue: 0,
            request_timeout: 0.0,
            cluster: ClusterConfig::default(),
            slo: SloConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from the JSON schema used by `sagesched --config` (all fields
    /// optional; unknown fields ignored).
    pub fn from_json(j: &Json) -> Result<ExperimentConfig, String> {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = j.f64_or("seed", cfg.seed as f64) as u64;
        if let Some(p) = j.get("policy").and_then(Json::as_str) {
            cfg.policy =
                PolicyKind::from_name(p).ok_or_else(|| format!("unknown policy {p}"))?;
        }
        if let Some(p) = j.get("predictor").and_then(Json::as_str) {
            cfg.predictor = PredictorKind::from_name(p)
                .ok_or_else(|| format!("unknown predictor {p}"))?;
        }
        if let Some(c) = j.get("cost_model").and_then(Json::as_str) {
            cfg.cost_model = CostModelKind::from_name(c)
                .ok_or_else(|| format!("unknown cost model {c}"))?;
        }
        if let Some(e) = j.get("engine").and_then(Json::as_str) {
            cfg.engine =
                EngineProfile::by_name(e).ok_or_else(|| format!("unknown engine {e}"))?;
        }
        if let Some(m) = j.get("preempt_mode").and_then(Json::as_str) {
            cfg.preempt_mode = match m {
                "recompute" => PreemptMode::Recompute,
                "swap" => PreemptMode::Swap,
                _ => return Err(format!("unknown preempt mode {m}")),
            };
        }
        cfg.similarity_threshold =
            j.f64_or("similarity_threshold", cfg.similarity_threshold as f64) as f32;
        cfg.history_capacity =
            j.f64_or("history_capacity", cfg.history_capacity as f64) as usize;
        cfg.bucket_tokens = j.f64_or("bucket_tokens", cfg.bucket_tokens as f64) as u32;
        if let Some(w) = j.get("workload") {
            cfg.workload.rps = w.f64_or("rps", cfg.workload.rps);
            cfg.workload.n_requests =
                w.f64_or("n_requests", cfg.workload.n_requests as f64) as usize;
            if let Some(a) = w.get("arrival") {
                let arr = &mut cfg.workload.arrival;
                if let Some(kind) = a.get("kind").and_then(Json::as_str) {
                    arr.kind = ArrivalKind::from_name(kind)
                        .ok_or_else(|| format!("unknown arrival kind {kind}"))?;
                }
                arr.burst_factor = a.f64_or("burst_factor", arr.burst_factor);
                arr.burst_on_mean = a.f64_or("burst_on_mean", arr.burst_on_mean);
                arr.burst_off_mean = a.f64_or("burst_off_mean", arr.burst_off_mean);
                arr.diurnal_period = a.f64_or("diurnal_period", arr.diurnal_period);
                arr.diurnal_amplitude =
                    a.f64_or("diurnal_amplitude", arr.diurnal_amplitude);
                arr.validate().map_err(|e| format!("workload.{e}"))?;
            }
            if let Some(arr) = w.get("mix").and_then(Json::as_arr) {
                let mut mix = Vec::new();
                for item in arr {
                    let name = item.str_or("dataset", "");
                    let ds = DatasetKind::from_name(name)
                        .ok_or_else(|| format!("unknown dataset {name}"))?;
                    mix.push((ds, item.f64_or("weight", 1.0)));
                }
                if !mix.is_empty() {
                    cfg.workload.mix = mix;
                }
            }
            if let Some(arr) = w.get("slo_mix").and_then(Json::as_arr) {
                let mut mix = Vec::new();
                for item in arr {
                    let name = item.str_or("class", "");
                    let class = SloClass::from_name(name)
                        .ok_or_else(|| format!("unknown slo class {name}"))?;
                    mix.push((class, item.f64_or("weight", 1.0)));
                }
                if !mix.is_empty() {
                    crate::slo::validate_mix(&mix)
                        .map_err(|e| format!("workload.{e}"))?;
                    cfg.workload.slo_mix = mix;
                }
            }
            if let Some(d) = w.get("drift") {
                let drift = &mut cfg.workload.drift;
                drift.at_fraction = d.f64_or("at_fraction", drift.at_fraction);
                if let Some(remap) = d.get("remap_topics").and_then(Json::as_bool) {
                    drift.remap_topics = remap;
                }
                if let Some(arr) = d.get("mix").and_then(Json::as_arr) {
                    let mut mix = Vec::new();
                    for item in arr {
                        let name = item.str_or("dataset", "");
                        let ds = DatasetKind::from_name(name)
                            .ok_or_else(|| format!("unknown dataset {name}"))?;
                        mix.push((ds, item.f64_or("weight", 1.0)));
                    }
                    drift.mix = mix;
                }
                drift.validate().map_err(|e| format!("workload.{e}"))?;
            }
            if let Some(s) = w.get("sessions") {
                let se = &mut cfg.workload.sessions;
                if let Some(enabled) = s.get("enabled").and_then(Json::as_bool) {
                    se.enabled = enabled;
                }
                se.prefix_share = s.f64_or("prefix_share", se.prefix_share);
                se.turns_mean = s.f64_or("turns_mean", se.turns_mean);
                se.think_mean = s.f64_or("think_mean", se.think_mean);
                se.system_prompt_tokens =
                    s.f64_or("system_prompt_tokens", se.system_prompt_tokens as f64) as u32;
                se.prompts_per_dataset =
                    s.f64_or("prompts_per_dataset", se.prompts_per_dataset as f64) as usize;
                se.validate().map_err(|e| format!("workload.{e}"))?;
            }
        }
        if let Some(s) = j.get("slo") {
            let slo = &mut cfg.slo;
            if let Some(aware) = s.get("class_aware").and_then(Json::as_bool) {
                slo.class_aware = aware;
            }
            slo.sched_quantile = s.f64_or("sched_quantile", slo.sched_quantile);
            slo.cost_time_scale = s.f64_or("cost_time_scale", slo.cost_time_scale);
            if let Some(classes) = s.get("classes").and_then(Json::as_arr) {
                for item in classes {
                    let name = item.str_or("class", "");
                    let class = SloClass::from_name(name)
                        .ok_or_else(|| format!("unknown slo class {name}"))?;
                    let spec = slo.specs.spec_mut(class);
                    spec.ttft_target = item.f64_or("ttft", spec.ttft_target);
                    spec.ttlt_target = item.f64_or("ttlt", spec.ttlt_target);
                    spec.weight = item.f64_or("weight", spec.weight);
                    spec.admit_fraction =
                        item.f64_or("admit_fraction", spec.admit_fraction);
                }
            }
            slo.validate()?;
        }
        if let Some(c) = j.get("cluster") {
            cfg.cluster.replicas =
                c.f64_or("replicas", cfg.cluster.replicas as f64) as usize;
            if let Some(r) = c.get("router").and_then(Json::as_str) {
                cfg.cluster.router = RouterKind::from_name(r)
                    .ok_or_else(|| format!("unknown router {r}"))?;
            }
            cfg.cluster.router_quantile =
                c.f64_or("router_quantile", cfg.cluster.router_quantile);
            if !(0.0 < cfg.cluster.router_quantile && cfg.cluster.router_quantile < 1.0) {
                return Err("cluster.router_quantile must be in (0,1)".to_string());
            }
            let default_steal = cfg.cluster.steal_transfer_per_token;
            cfg.cluster.steal_transfer_per_token =
                c.f64_or("steal_transfer_per_token", default_steal);
            if cfg.cluster.steal_transfer_per_token < 0.0 {
                return Err("cluster.steal_transfer_per_token must be >= 0".to_string());
            }
            let f64_list = |key: &str| -> Result<Vec<f64>, String> {
                match c.get(key).and_then(Json::as_arr) {
                    None => Ok(Vec::new()),
                    Some(arr) => arr
                        .iter()
                        .map(|v| {
                            v.as_f64()
                                .ok_or_else(|| format!("cluster.{key}: non-numeric entry"))
                        })
                        .collect(),
                }
            };
            let speeds = f64_list("speeds")?;
            if speeds.iter().any(|&v| v <= 0.0) {
                return Err("cluster.speeds entries must be positive".to_string());
            }
            if !speeds.is_empty() {
                cfg.cluster.speeds = speeds;
            }
            let batches = f64_list("batch_sizes")?;
            if batches.iter().any(|&b| b < 1.0) {
                return Err("cluster.batch_sizes entries must be >= 1".to_string());
            }
            if !batches.is_empty() {
                cfg.cluster.batch_sizes = batches.iter().map(|&b| b as usize).collect();
            }
            let kvs = f64_list("kv_capacities")?;
            if kvs.iter().any(|&k| k < crate::serve::KV_BLOCK_TOKENS as f64) {
                return Err(format!(
                    "cluster.kv_capacities entries must be >= {} tokens (one KV block)",
                    crate::serve::KV_BLOCK_TOKENS
                ));
            }
            if !kvs.is_empty() {
                cfg.cluster.kv_capacities = kvs.iter().map(|&k| k as usize).collect();
            }
            if let Some(fails) = c.get("failures").and_then(Json::as_arr) {
                let mut failures = Vec::new();
                for f in fails {
                    let replica = f
                        .get("replica")
                        .and_then(Json::as_u64)
                        .ok_or_else(|| {
                            "cluster.failures: missing replica index".to_string()
                        })? as usize;
                    let at = f.f64_or("at", -1.0);
                    let duration = f.f64_or("duration", 0.0);
                    let ev = FailureEvent { replica, at, duration };
                    ev.validate().map_err(|e| format!("cluster.failures: {e}"))?;
                    failures.push(ev);
                }
                cfg.cluster.failures = failures;
            }
            if let Some(doms) = c.get("failure_domains").and_then(Json::as_arr) {
                let mut domains = Vec::new();
                for (k, d) in doms.iter().enumerate() {
                    let name = d
                        .get("name")
                        .and_then(Json::as_str)
                        .map(str::to_string)
                        .unwrap_or_else(|| format!("domain{k}"));
                    let members = d
                        .get("replicas")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| {
                            "cluster.failure_domains: missing replicas list".to_string()
                        })?;
                    let mut replicas = Vec::with_capacity(members.len());
                    for m in members {
                        let idx = m.as_u64().ok_or_else(|| {
                            "cluster.failure_domains: non-integer replica index"
                                .to_string()
                        })? as usize;
                        replicas.push(idx);
                    }
                    if replicas.is_empty() {
                        return Err(format!(
                            "cluster.failure_domains: domain {name} has no replicas"
                        ));
                    }
                    domains.push(FailureDomain { name, replicas });
                }
                cfg.cluster.failure_domains = domains;
            }
            if let Some(fails) = c.get("domain_failures").and_then(Json::as_arr) {
                let mut events = Vec::new();
                for f in fails {
                    let domain = f
                        .get("domain")
                        .and_then(Json::as_u64)
                        .ok_or_else(|| {
                            "cluster.domain_failures: missing domain index".to_string()
                        })? as usize;
                    let at = f.f64_or("at", -1.0);
                    let duration = f.f64_or("duration", 0.0);
                    let ev = DomainFailureEvent { domain, at, duration };
                    ev.validate()
                        .map_err(|e| format!("cluster.domain_failures: {e}"))?;
                    events.push(ev);
                }
                cfg.cluster.domain_failures = events;
            }
            cfg.cluster.migration_kv_per_token =
                c.f64_or("migration_kv_per_token", cfg.cluster.migration_kv_per_token);
            cfg.cluster.migration_quantile =
                c.f64_or("migration_quantile", cfg.cluster.migration_quantile);
            if let Some(pools) = c.get("pools").and_then(Json::as_arr) {
                let mut parsed = Vec::with_capacity(pools.len());
                for p in pools {
                    let name = p.as_str().ok_or_else(|| {
                        "cluster.pools: entries must be strings".to_string()
                    })?;
                    parsed.push(PoolRole::from_name(name).ok_or_else(|| {
                        format!("cluster.pools: unknown pool role {name}")
                    })?);
                }
                cfg.cluster.pools = parsed;
            }
            cfg.cluster.transfer_bandwidth =
                c.f64_or("transfer_bandwidth", cfg.cluster.transfer_bandwidth);
            cfg.cluster.transfer_links =
                c.f64_or("transfer_links", cfg.cluster.transfer_links as f64) as usize;
            if let Some(r) = c.get("decode_router").and_then(Json::as_str) {
                cfg.cluster.decode_router = Some(
                    RouterKind::from_name(r)
                        .ok_or_else(|| format!("unknown decode_router {r}"))?,
                );
            }
            let shortlist = c.f64_or("shortlist_k", cfg.cluster.shortlist_k as f64);
            if shortlist < 1.0 {
                // negative values must be rejected *before* the usize cast
                // below silently wraps them into huge widths
                return Err("cluster.shortlist_k must be >= 1".to_string());
            }
            cfg.cluster.shortlist_k = shortlist as usize;
            cfg.cluster.validate()?;
            if let Some(a) = c.get("autoscale") {
                let asc = &mut cfg.cluster.autoscale;
                if let Some(kind) = a.get("kind").and_then(Json::as_str) {
                    asc.kind = AutoscaleKind::from_name(kind)
                        .ok_or_else(|| format!("unknown autoscale kind {kind}"))?;
                }
                if let Some(steps) = a.get("steps").and_then(Json::as_arr) {
                    let mut parsed = Vec::new();
                    for s in steps {
                        let at = s.f64_or("at", -1.0);
                        let target = s
                            .get("target")
                            .and_then(Json::as_u64)
                            .ok_or_else(|| {
                                "cluster.autoscale.steps: missing target".to_string()
                            })? as usize;
                        parsed.push(ScaleStep { at, target });
                    }
                    asc.steps = parsed;
                }
                asc.min_replicas = a.f64_or("min_replicas", asc.min_replicas as f64) as usize;
                asc.max_replicas = a.f64_or("max_replicas", asc.max_replicas as f64) as usize;
                asc.provision_delay = a.f64_or("provision_delay", asc.provision_delay);
                asc.cooldown = a.f64_or("cooldown", asc.cooldown);
                asc.interval = a.f64_or("interval", asc.interval);
                asc.high_watermark = a.f64_or("high_watermark", asc.high_watermark);
                asc.low_watermark = a.f64_or("low_watermark", asc.low_watermark);
                asc.kv_high_watermark = a.f64_or("kv_high_watermark", asc.kv_high_watermark);
                asc.kv_low_watermark = a.f64_or("kv_low_watermark", asc.kv_low_watermark);
                asc.quantile = a.f64_or("quantile", asc.quantile);
                asc.work_per_replica = a.f64_or("work_per_replica", asc.work_per_replica);
                if let Some(p) = a.get("prewarm").and_then(Json::as_bool) {
                    asc.prewarm = p;
                }
                asc.validate().map_err(|e| format!("cluster.{e}"))?;
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_roundtrip() {
        for p in PolicyKind::ALL {
            assert_eq!(PolicyKind::from_name(p.name()), Some(p));
        }
        assert_eq!(PolicyKind::from_name("nope"), None);
    }

    #[test]
    fn dataset_names_roundtrip() {
        for d in DatasetKind::ALL {
            assert_eq!(DatasetKind::from_name(d.name()), Some(d));
        }
    }

    #[test]
    fn default_config_is_paper_defaults() {
        let c = ExperimentConfig::default();
        assert_eq!(c.similarity_threshold, 0.8);
        assert_eq!(c.history_capacity, 10_000);
        assert_eq!(c.bucket_tokens, 200);
        assert_eq!(c.policy, PolicyKind::SageSched);
    }

    #[test]
    fn from_json_overrides() {
        let j = Json::parse(
            r#"{"policy":"fcfs","similarity_threshold":0.9,
                "workload":{"rps":4,"n_requests":10,
                  "mix":[{"dataset":"alpaca","weight":2}]}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.policy, PolicyKind::Fcfs);
        assert_eq!(c.similarity_threshold, 0.9);
        assert_eq!(c.workload.rps, 4.0);
        assert_eq!(c.workload.mix, vec![(DatasetKind::Alpaca, 2.0)]);
    }

    #[test]
    fn from_json_rejects_unknown_policy() {
        let j = Json::parse(r#"{"policy":"zzz"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn from_json_parses_drift_block() {
        let j = Json::parse(
            r#"{"predictor":"ranking","workload":{"drift":{
                "at_fraction":0.4,"remap_topics":false,
                "mix":[{"dataset":"write","weight":3}]}}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.predictor, PredictorKind::Ranking);
        assert_eq!(c.workload.drift.at_fraction, 0.4);
        assert!(!c.workload.drift.remap_topics);
        assert_eq!(c.workload.drift.mix, vec![(DatasetKind::Write, 3.0)]);
        assert!(c.workload.drift.enabled());
        // defaults: drift off
        assert!(!WorkloadConfig::default().drift.enabled());
        // out-of-range fraction rejected
        let bad =
            Json::parse(r#"{"workload":{"drift":{"at_fraction":1.5}}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
    }

    #[test]
    fn router_names_roundtrip() {
        for r in RouterKind::ALL {
            assert_eq!(RouterKind::from_name(r.name()), Some(r));
        }
        assert_eq!(RouterKind::from_name("nope"), None);
    }

    #[test]
    fn scaled_profile_divides_time_constants() {
        let base = EngineProfile::a40_llama8b();
        let fast = base.scaled(2.0);
        assert!((fast.decode_c0 - base.decode_c0 / 2.0).abs() < 1e-15);
        assert!((fast.prefill_p1 - base.prefill_p1 / 2.0).abs() < 1e-15);
        assert_eq!(fast.max_batch, base.max_batch);
        assert_eq!(fast.kv_capacity, base.kv_capacity);
    }

    #[test]
    fn cluster_config_cycles_heterogeneity() {
        let base = EngineProfile::a40_llama8b();
        let cc = ClusterConfig {
            replicas: 4,
            speeds: vec![1.0, 0.5],
            batch_sizes: vec![64],
            kv_capacities: vec![8000, 4000],
            ..ClusterConfig::default()
        };
        assert_eq!(cc.speed_of(0), 1.0);
        assert_eq!(cc.speed_of(1), 0.5);
        assert_eq!(cc.speed_of(2), 1.0);
        let p1 = cc.replica_profile(&base, 1);
        assert_eq!(p1.max_batch, 64);
        assert_eq!(p1.kv_capacity, 4000);
        assert!((p1.decode_c0 - base.decode_c0 * 2.0).abs() < 1e-12);
    }

    #[test]
    fn from_json_parses_cluster_block() {
        let j = Json::parse(
            r#"{"cluster":{"replicas":6,"router":"cost-aware",
                "speeds":[1.0,0.5],"kv_capacities":[9000]}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.cluster.replicas, 6);
        assert_eq!(c.cluster.router, RouterKind::CostAware);
        assert_eq!(c.cluster.speeds, vec![1.0, 0.5]);
        assert_eq!(c.cluster.kv_capacities, vec![9000]);
        let bad = Json::parse(r#"{"cluster":{"router":"zzz"}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
    }

    #[test]
    fn arrival_names_roundtrip() {
        for a in ArrivalKind::ALL {
            assert_eq!(ArrivalKind::from_name(a.name()), Some(a));
        }
        assert_eq!(ArrivalKind::from_name("nope"), None);
    }

    #[test]
    fn from_json_parses_arrival_block() {
        let j = Json::parse(
            r#"{"workload":{"arrival":{"kind":"mmpp","burst_factor":4,
                "burst_on_mean":5,"burst_off_mean":20}}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.workload.arrival.kind, ArrivalKind::Mmpp);
        assert_eq!(c.workload.arrival.burst_factor, 4.0);
        assert_eq!(c.workload.arrival.burst_on_mean, 5.0);
        let bad = Json::parse(r#"{"workload":{"arrival":{"kind":"zzz"}}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
        let bad =
            Json::parse(r#"{"workload":{"arrival":{"burst_factor":0.5}}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
    }

    #[test]
    fn failure_list_grammar_roundtrips_and_rejects_garbage() {
        let evs = FailureEvent::parse_list("1@30+10, 0@60+5").unwrap();
        assert_eq!(
            evs,
            vec![
                FailureEvent { replica: 1, at: 30.0, duration: 10.0 },
                FailureEvent { replica: 0, at: 60.0, duration: 5.0 },
            ]
        );
        for bad in ["1@30", "x@1+1", "1@x+1", "1@1+x", "1@-1+5", "1@5+0", "1@NaN+5"] {
            assert!(FailureEvent::parse_list(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn from_json_parses_failures() {
        let j = Json::parse(
            r#"{"cluster":{"failures":[{"replica":1,"at":30,"duration":10}]}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(
            c.cluster.failures,
            vec![FailureEvent { replica: 1, at: 30.0, duration: 10.0 }]
        );
        let bad = Json::parse(
            r#"{"cluster":{"failures":[{"replica":1,"at":30,"duration":0}]}}"#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"cluster":{"failures":[{"at":30}]}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
    }

    #[test]
    fn autoscale_names_roundtrip() {
        for k in AutoscaleKind::ALL {
            assert_eq!(AutoscaleKind::from_name(k.name()), Some(k));
        }
        assert_eq!(AutoscaleKind::from_name("nope"), None);
    }

    #[test]
    fn scale_step_grammar_roundtrips_and_rejects_garbage() {
        let steps = ScaleStep::parse_list("10@6, 40@2").unwrap();
        assert_eq!(
            steps,
            vec![
                ScaleStep { at: 10.0, target: 6 },
                ScaleStep { at: 40.0, target: 2 },
            ]
        );
        for bad in ["10", "x@2", "10@x", "-1@2", "10@0", "NaN@3"] {
            assert!(ScaleStep::parse_list(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn autoscale_config_validation() {
        let mut a = AutoscaleConfig::default();
        assert!(a.validate().is_ok());
        a.kind = AutoscaleKind::Step;
        assert!(a.validate().is_err(), "step schedule without steps");
        a.steps = vec![ScaleStep { at: 5.0, target: 3 }];
        assert!(a.validate().is_ok());
        a.min_replicas = 8;
        a.max_replicas = 4;
        assert!(a.validate().is_err(), "min > max");
        a = AutoscaleConfig::default();
        a.quantile = 1.5;
        assert!(a.validate().is_err(), "quantile out of range");
        a = AutoscaleConfig::default();
        a.low_watermark = 9.0;
        assert!(a.validate().is_err(), "low watermark above high");
    }

    #[test]
    fn from_json_parses_autoscale_block() {
        let j = Json::parse(
            r#"{"cluster":{"autoscale":{"kind":"uncertainty","min_replicas":2,
                "max_replicas":6,"quantile":0.95,"work_per_replica":500000,
                "provision_delay":1.5,"prewarm":true},
                "router":"quantile-cost","router_quantile":0.8,
                "steal_transfer_per_token":5}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.cluster.autoscale.kind, AutoscaleKind::UncertaintyAware);
        assert_eq!(c.cluster.autoscale.min_replicas, 2);
        assert_eq!(c.cluster.autoscale.max_replicas, 6);
        assert_eq!(c.cluster.autoscale.quantile, 0.95);
        assert_eq!(c.cluster.autoscale.work_per_replica, 500_000.0);
        assert_eq!(c.cluster.autoscale.provision_delay, 1.5);
        assert!(c.cluster.autoscale.prewarm);
        assert_eq!(c.cluster.router, RouterKind::QuantileCost);
        assert_eq!(c.cluster.router_quantile, 0.8);
        assert_eq!(c.cluster.steal_transfer_per_token, 5.0);
        let j = Json::parse(
            r#"{"cluster":{"autoscale":{"kind":"step",
                "steps":[{"at":10,"target":6},{"at":40,"target":2}]}}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.cluster.autoscale.kind, AutoscaleKind::Step);
        assert_eq!(
            c.cluster.autoscale.steps,
            vec![
                ScaleStep { at: 10.0, target: 6 },
                ScaleStep { at: 40.0, target: 2 },
            ]
        );
        for bad in [
            r#"{"cluster":{"autoscale":{"kind":"zzz"}}}"#,
            r#"{"cluster":{"autoscale":{"kind":"step"}}}"#,
            r#"{"cluster":{"autoscale":{"quantile":2.0}}}"#,
            r#"{"cluster":{"router_quantile":1.5}}"#,
            r#"{"cluster":{"steal_transfer_per_token":-1}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn from_json_parses_disagg_blocks() {
        let j = Json::parse(
            r#"{"cluster":{"replicas":4,"pools":["prefill","decode"],
                "transfer_bandwidth":5000,"transfer_links":3,
                "decode_router":"least-kv"}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert!(c.cluster.disagg());
        assert_eq!(c.cluster.pools, vec![PoolRole::Prefill, PoolRole::Decode]);
        // roles cycle over replica indices like the heterogeneity vectors
        assert_eq!(c.cluster.pool_of(2), Some(PoolRole::Prefill));
        assert_eq!(c.cluster.pool_of(3), Some(PoolRole::Decode));
        assert_eq!(c.cluster.transfer_bandwidth, 5000.0);
        assert_eq!(c.cluster.transfer_links, 3);
        assert_eq!(c.cluster.decode_router, Some(RouterKind::LeastKv));
    }

    #[test]
    fn cluster_validate_rejects_out_of_range_knobs() {
        // migration_quantile out of (0,1) must be a hard config error on
        // every surface, not silently fed into normal_quantile
        for bad in [
            r#"{"cluster":{"migration_quantile":1.0}}"#,
            r#"{"cluster":{"migration_quantile":0.0}}"#,
            r#"{"cluster":{"migration_quantile":-0.5}}"#,
            r#"{"cluster":{"migration_kv_per_token":-1}}"#,
            r#"{"cluster":{"transfer_bandwidth":0}}"#,
            r#"{"cluster":{"transfer_bandwidth":-2}}"#,
            r#"{"cluster":{"transfer_links":0}}"#,
            r#"{"cluster":{"shortlist_k":0}}"#,
            r#"{"cluster":{"shortlist_k":-4}}"#,
            r#"{"cluster":{"pools":["prefill"]}}"#,
            r#"{"cluster":{"pools":["zzz","decode"]}}"#,
            r#"{"cluster":{"replicas":1,"pools":["prefill","decode"]}}"#,
            r#"{"cluster":{"replicas":2,"pools":["decode","decode"]}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        // the shared validator also rejects NaN knobs CLI parsing can produce
        let mut c = ClusterConfig::default();
        c.steal_transfer_per_token = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = ClusterConfig::default();
        c.migration_quantile = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = ClusterConfig::default();
        c.transfer_bandwidth = f64::INFINITY;
        assert!(c.validate().is_err());
        let mut c = ClusterConfig::default();
        c.shortlist_k = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_json_parses_shortlist_k() {
        let j = Json::parse(r#"{"cluster":{"shortlist_k":3}}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.cluster.shortlist_k, 3);
        // omitted → the safe default
        let j = Json::parse(r#"{"cluster":{}}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.cluster.shortlist_k, ClusterConfig::default().shortlist_k);
    }

    #[test]
    fn from_json_parses_slo_blocks() {
        let j = Json::parse(
            r#"{"slo":{"class_aware":true,"sched_quantile":0.95,
                "classes":[{"class":"interactive","ttft":1.5,"ttlt":15,
                            "weight":8,"admit_fraction":1.0},
                           {"class":"batch","admit_fraction":0.5}]},
                "workload":{"slo_mix":[{"class":"interactive","weight":0.6},
                                       {"class":"batch","weight":0.4}]}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert!(c.slo.class_aware);
        assert_eq!(c.slo.sched_quantile, 0.95);
        let spec = c.slo.specs.spec(SloClass::Interactive);
        assert_eq!(spec.ttft_target, 1.5);
        assert_eq!(spec.ttlt_target, 15.0);
        assert_eq!(spec.weight, 8.0);
        assert_eq!(c.slo.specs.spec(SloClass::Batch).admit_fraction, 0.5);
        // untouched class keeps its default
        assert_eq!(c.slo.specs.spec(SloClass::Standard).weight, 1.0);
        assert_eq!(
            c.workload.slo_mix,
            vec![(SloClass::Interactive, 0.6), (SloClass::Batch, 0.4)]
        );
        for bad in [
            r#"{"slo":{"classes":[{"class":"zzz"}]}}"#,
            r#"{"slo":{"sched_quantile":2.0}}"#,
            r#"{"slo":{"classes":[{"class":"batch","weight":-1}]}}"#,
            r#"{"workload":{"slo_mix":[{"class":"zzz","weight":1}]}}"#,
            r#"{"workload":{"slo_mix":[{"class":"batch","weight":0}]}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn engine_profiles_sane() {
        for e in [EngineProfile::a40_llama8b(), EngineProfile::h800_qwen32b()] {
            assert!(e.kv_capacity > 1000);
            assert!(e.decode_c0 > 0.0 && e.decode_m1 > 0.0);
            assert!(EngineProfile::by_name(&e.name).is_some());
        }
    }
}
