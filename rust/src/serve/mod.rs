//! The serving coordinator: continuous batching + admission + preemption.
//!
//! One iteration of the loop (vLLM-style iteration-level scheduling):
//!
//! 1. ingest arrivals up to the current time; predict each new request's
//!    output-length distribution and derive its cost distribution;
//! 2. ask the [`crate::sched::Policy`] for every live request's priority;
//! 3. pack the decode batch greedily in priority order under the KV-memory
//!    and batch-size constraints ([`crate::kvcache::KvManager`] does the
//!    block math);
//! 4. preempt running requests that lost their slot (swap-out or drop);
//!    prefill / swap-in newly admitted ones (exclusive, charged to the
//!    engine clock);
//! 5. run one decode step on the [`crate::engine::Engine`]; record emitted
//!    tokens, completions (TTFT/TTLT), and feed completions back to the
//!    predictor (the history window learns online).
//!
//! The same loop drives the simulator and the real PJRT engine.

use std::time::Instant;

use crate::config::{ExperimentConfig, PreemptMode};
use crate::core::{Phase, Request, RequestOutcome};
use crate::cost::CostModel;
use crate::distribution::LengthDist;
use crate::engine::{Engine, LaneState, SimEngine};
use crate::kvcache::{KvManager, KvResidence};
use crate::metrics::RunReport;
use crate::predictor::Predictor;
use crate::sched::{Policy, ReqView};
use crate::slo::{ClassAwarePolicy, SloClass, SloConfig};
use crate::workload::WorkloadGen;

/// KV block size in tokens (defined in [`crate::core`] so the workload
/// generator's prefix chains and the block math agree; re-exported here for
/// the serving-side call sites).
pub use crate::core::KV_BLOCK_TOKENS;

/// A partially-generated request handed off between replicas at scale-in
/// migration: the [`Request`] plus the serving progress that must survive
/// the move. The generated prefix is *kept* — the receiving coordinator
/// resumes the request like a preempted one (recompute-mode re-prefill of
/// prompt + prefix, the KV-reconstruction work a real migration pays after
/// the transfer), it does not restart it — and the first-token timestamp
/// rides along so TTFT accounting stays honest across the move.
#[derive(Clone, Debug)]
pub struct MigratedRequest {
    pub req: Request,
    /// Tokens already generated on the source replica.
    pub generated: u32,
    /// When the first token was emitted (None if none was — callers only
    /// migrate requests with `generated > 0`, which always have one).
    pub first_token: Option<f64>,
    /// Preemptions suffered so far (carried into the outcome).
    pub preemptions: u32,
}

/// A live request inside the coordinator.
struct Live {
    req: Request,
    phase: Phase,
    generated: u32,
    first_token: Option<f64>,
    preemptions: u32,
    pred_lengths: LengthDist,
    cost_dist: LengthDist,
    point_pred: f64,
    rank_pred: f64,
    priority: f64,
    /// Effective prompt length after the prefix-cache probe at submission:
    /// `input_len` minus tokens expected to be served warm. Cost/priority
    /// math uses this so SSJF/Gittins ordering sees true post-hit cost.
    eff_input: u32,
}

/// The coordinator: generic over the engine type (simulator or the real
/// PJRT engine), with boxed policy/predictor/cost-model strategies.
pub struct Coordinator<E: Engine> {
    pub engine: E,
    pub policy: Box<dyn Policy>,
    pub predictor: Box<dyn Predictor>,
    pub cost_model: Box<dyn CostModel>,
    pub kv: KvManager,
    pub preempt_mode: PreemptMode,
    /// uniform-noise mixing weight for fig11 (0 = off)
    pub noise_mix: f64,
    /// IO-aware preemption margin: a pending challenger must beat a running
    /// request's priority by this relative factor to displace it
    /// (paper appendix, SageSched aspect (iii); 0 = plain priority order)
    pub preempt_hysteresis: f64,
    /// IO-aware preemption: running requests predicted to finish within
    /// this many tokens are never displaced (0 = off)
    pub preempt_finish_guard: u32,
    /// Admission control: reject submissions once this many requests are
    /// live (0 = unbounded)
    pub max_queue: usize,
    /// Abort requests still queued after this many seconds (0 = never)
    pub request_timeout: f64,
    /// SLO tier table + class-aware switch: with `class_aware` on, each
    /// class only admits while the live set is below its `admit_fraction`
    /// of `max_queue` (Batch yields headroom to Interactive under
    /// overload); off, admission is class-blind exactly as before.
    pub slo: SloConfig,
    now: f64,
    live: Vec<Live>,
    outcomes: Vec<RequestOutcome>,
    /// Windowed rank quality of the predictor: (rank score at admission,
    /// realized output length) pushed once per first completion.
    pub pred_tau: crate::util::stats::KendallTau,
    /// Request ids already fed to `predictor.observe` — guards against
    /// double-counting an observation when a request re-enters this
    /// coordinator (failure re-route, migration bounce-back).
    observed: std::collections::HashSet<crate::core::RequestId>,
    /// requests rejected at admission (queue full)
    pub rejected: u64,
    /// requests aborted after timing out in the queue
    pub aborted: u64,
    /// per-SLO-class rejections (indexed by [`SloClass::index`])
    pub rejected_by_class: [u64; 3],
    /// per-SLO-class timeout aborts (indexed by [`SloClass::index`])
    pub aborted_by_class: [u64; 3],
    preemption_count: u64,
    predict_overhead: f64,
    sched_overhead: f64,
    /// Called for each completion *before* the engine evicts the request
    /// (the HTTP server uses this to pull generated text out of the real
    /// engine).
    #[allow(clippy::type_complexity)]
    pub on_complete: Option<Box<dyn FnMut(&RequestOutcome, &mut E) + Send>>,
}

impl<E: Engine> Coordinator<E> {
    pub fn new(
        engine: E,
        policy: Box<dyn Policy>,
        predictor: Box<dyn Predictor>,
        cost_model: Box<dyn CostModel>,
        preempt_mode: PreemptMode,
    ) -> Coordinator<E> {
        let kv = KvManager::new(engine.kv_capacity(), KV_BLOCK_TOKENS);
        Coordinator {
            engine,
            policy,
            predictor,
            cost_model,
            kv,
            preempt_mode,
            noise_mix: 0.0,
            preempt_hysteresis: 0.0,
            preempt_finish_guard: 0,
            max_queue: 0,
            request_timeout: 0.0,
            slo: SloConfig::default(),
            now: 0.0,
            live: Vec::new(),
            outcomes: Vec::new(),
            pred_tau: crate::util::stats::KendallTau::new(256),
            observed: Default::default(),
            rejected: 0,
            aborted: 0,
            rejected_by_class: [0; 3],
            aborted_by_class: [0; 3],
            preemption_count: 0,
            predict_overhead: 0.0,
            sched_overhead: 0.0,
            on_complete: None,
        }
    }

    /// Advance the clock to (at least) `t` — the real-time server uses this
    /// to keep coordinator time aligned with wallclock.
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Whether the coordinator has no live (queued/running/preempted)
    /// requests. External drivers — the HTTP server and the event-driven
    /// cluster — use this to decide whether [`Coordinator::step`] can make
    /// progress or the clock should jump to the next arrival.
    pub fn is_idle(&self) -> bool {
        self.live.is_empty()
    }

    /// Whether a request id is still live inside the coordinator (queued,
    /// running, or preempted). The cluster layer uses this to reconcile
    /// its routing bookkeeping with timeout-aborted requests, which leave
    /// the live set without ever producing an outcome.
    pub fn is_live(&self, id: crate::core::RequestId) -> bool {
        self.live.iter().any(|l| l.req.id == id)
    }

    pub fn outcomes(&self) -> &[RequestOutcome] {
        &self.outcomes
    }

    /// Whether a request of `class` would be admitted right now. With
    /// class-aware SLO serving each class fills only its `admit_fraction`
    /// of the queue bound (so under overload Batch is refused while
    /// headroom remains for Interactive); class-blind, this is the plain
    /// `live < max_queue` check. The cluster's dispatcher consults this
    /// before routing so its has-room view can never disagree with the
    /// admission verdict.
    pub fn admits(&self, class: SloClass) -> bool {
        if self.max_queue == 0 {
            return true;
        }
        let cap = if self.slo.class_aware {
            let f = self.slo.specs.spec(class).admit_fraction;
            ((self.max_queue as f64 * f).ceil() as usize).clamp(1, self.max_queue)
        } else {
            self.max_queue
        };
        self.live.len() < cap
    }

    /// Admit one request (predict + derive cost distribution). Returns
    /// false (rejecting the request) when admission control is enabled and
    /// the live set is full for the request's class (see
    /// [`Coordinator::admits`]).
    pub fn submit(&mut self, req: Request) -> bool {
        self.submit_with(req, false)
    }

    /// Admission-exempt submission for *migrations* (work stealing,
    /// scale-in drain fallback): the request already passed admission on
    /// another replica, so moving it must never convert it into a
    /// rejection.
    pub fn submit_exempt(&mut self, req: Request) -> bool {
        self.submit_with(req, true)
    }

    fn submit_with(&mut self, req: Request, exempt: bool) -> bool {
        if !exempt && !self.admits(req.slo) {
            self.rejected += 1;
            self.rejected_by_class[req.slo.index()] += 1;
            return false;
        }
        let t0 = Instant::now();
        let mut pred = self.predictor.predict(&req);
        let point = self.predictor.predict_point(&req);
        let rank = self.predictor.predict_rank(&req);
        self.predict_overhead += t0.elapsed().as_secs_f64();
        if self.noise_mix > 0.0 {
            let noise = LengthDist::uniform(1.0, (pred.max() * 2.0).max(64.0), 24);
            pred = pred.mix(&noise, self.noise_mix);
        }
        // probe the prefix cache: warm tokens skip prefill, so the cost
        // distribution the scheduler ranks by is built on the *effective*
        // prompt length (a prediction — the warm blocks can still be
        // evicted before admission, which only makes us conservative)
        let cached = self
            .kv
            .cached_prefix_tokens(&req.prefix_key, req.input_len as usize);
        let eff_input = req.input_len - (cached as u32).min(req.input_len);
        let cost_dist = self.cost_model.cost_dist(eff_input, &pred);
        self.live.push(Live {
            req,
            phase: Phase::Queued,
            generated: 0,
            first_token: None,
            preemptions: 0,
            pred_lengths: pred,
            cost_dist,
            point_pred: point,
            rank_pred: rank,
            priority: f64::INFINITY,
            eff_input,
        });
        true
    }

    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Live requests still waiting for their first admission (queued phase,
    /// zero tokens generated). These hold no KV or engine state, which makes
    /// them safe to migrate to another replica.
    pub fn queued_count(&self) -> usize {
        self.live
            .iter()
            .filter(|l| l.phase == Phase::Queued && l.generated == 0)
            .count()
    }

    /// Remove and return up to `max` never-scheduled requests (queued phase,
    /// zero tokens generated), newest arrivals first so the head of the line
    /// keeps its place. The cluster's work stealing uses this: such requests
    /// hold no KV or engine state, so handing them to another replica needs
    /// no state transfer.
    pub fn drain_queued(&mut self, max: usize) -> Vec<Request> {
        if max == 0 {
            return Vec::new();
        }
        let mut idx: Vec<usize> = (0..self.live.len())
            .filter(|&i| self.live[i].phase == Phase::Queued && self.live[i].generated == 0)
            .collect();
        idx.sort_by(|&a, &b| {
            let (la, lb) = (&self.live[a], &self.live[b]);
            lb.req
                .arrival
                .partial_cmp(&la.req.arrival)
                .unwrap()
                .then(lb.req.id.cmp(&la.req.id))
        });
        idx.truncate(max);
        // remove back-to-front so earlier indices stay valid under swap_remove
        idx.sort_unstable_by(|a, b| b.cmp(a));
        let mut out = Vec::with_capacity(idx.len());
        for i in idx {
            let l = self.live.swap_remove(i);
            self.policy.forget(l.req.id);
            out.push(l.req);
        }
        out
    }

    /// (id, input_len, arrival) of every never-scheduled queued request,
    /// newest arrivals first — the same order [`Coordinator::drain_queued`]
    /// removes them. The cluster's transfer-cost-gated work stealing uses
    /// this to evaluate each candidate's migration penalty *before*
    /// draining anything.
    pub fn queued_meta(&self) -> Vec<(crate::core::RequestId, u32, f64)> {
        let mut v: Vec<&Live> = self
            .live
            .iter()
            .filter(|l| l.phase == Phase::Queued && l.generated == 0)
            .collect();
        v.sort_by(|a, b| {
            b.req
                .arrival
                .partial_cmp(&a.req.arrival)
                .unwrap()
                .then(b.req.id.cmp(&a.req.id))
        });
        v.into_iter()
            .map(|l| (l.req.id, l.req.input_len, l.req.arrival))
            .collect()
    }

    /// Borrow a never-scheduled queued request by id (None for unknown ids
    /// or requests already holding engine/KV state). The cluster's work
    /// stealing reads the prefix chain through this to price the warm
    /// cache state a steal would abandon on the victim.
    pub fn queued_request(&self, id: crate::core::RequestId) -> Option<&Request> {
        self.live
            .iter()
            .find(|l| l.req.id == id && l.phase == Phase::Queued && l.generated == 0)
            .map(|l| &l.req)
    }

    /// Remove and return the never-scheduled queued requests with these ids
    /// (in the order given); ids that are unknown or already scheduled are
    /// skipped. Like [`Coordinator::drain_queued`], the removed requests
    /// hold no KV or engine state, so handing them to another replica needs
    /// no state transfer.
    pub fn drain_ids(&mut self, ids: &[crate::core::RequestId]) -> Vec<Request> {
        let mut out = Vec::with_capacity(ids.len());
        for &id in ids {
            let found = self.live.iter().position(|l| {
                l.req.id == id && l.phase == Phase::Queued && l.generated == 0
            });
            if let Some(i) = found {
                let l = self.live.swap_remove(i);
                self.policy.forget(l.req.id);
                out.push(l.req);
            }
        }
        out
    }

    /// Remove and return *all* live requests, releasing their KV, engine and
    /// policy state. Models a replica crash: generated prefixes are lost and
    /// the requests must be re-dispatched from scratch elsewhere (their
    /// original arrival times are preserved so latency accounting still
    /// charges the full wait).
    pub fn drain_live(&mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.live.len());
        for l in std::mem::take(&mut self.live) {
            self.kv.release(l.req.id);
            self.policy.forget(l.req.id);
            self.engine.evict(l.req.id);
            out.push(l.req);
        }
        out
    }

    /// (id, input_len, generated) of every *partially-generated* live
    /// request — one holding engine/KV progress (`generated > 0`:
    /// running, preempted, or re-queued after a migration) — in ascending
    /// id order so callers iterate deterministically. The cluster's
    /// migration-cost-aware scale-in uses this to price each candidate's
    /// remaining work against its KV transfer cost *before* draining
    /// anything.
    pub fn partial_meta(&self) -> Vec<(crate::core::RequestId, u32, u32)> {
        let mut v: Vec<(crate::core::RequestId, u32, u32)> = self
            .live
            .iter()
            .filter(|l| l.generated > 0)
            .map(|l| (l.req.id, l.req.input_len, l.generated))
            .collect();
        v.sort_unstable_by_key(|e| e.0);
        v
    }

    /// Whether any live request holds a generated prefix (`generated > 0`)
    /// — the cheap O(live) gate the transfer fabric polls before paying
    /// [`Coordinator::partial_meta`]'s allocation + sort.
    pub fn has_partials(&self) -> bool {
        self.live.iter().any(|l| l.generated > 0)
    }

    /// Remove and return the partially-generated live requests with these
    /// ids (in the order given), releasing their KV, engine, and policy
    /// state on *this* replica; ids that are unknown or hold no progress
    /// are skipped. Unlike [`Coordinator::drain_live`] (crash semantics),
    /// the returned [`MigratedRequest`]s keep their generated prefix and
    /// first-token timestamp — the receiving replica resumes them via
    /// [`Coordinator::submit_migrated`].
    pub fn drain_partials(&mut self, ids: &[crate::core::RequestId]) -> Vec<MigratedRequest> {
        let mut out = Vec::with_capacity(ids.len());
        for &id in ids {
            let found = self
                .live
                .iter()
                .position(|l| l.req.id == id && l.generated > 0);
            if let Some(i) = found {
                let l = self.live.swap_remove(i);
                self.kv.release(l.req.id);
                self.policy.forget(l.req.id);
                self.engine.evict(l.req.id);
                out.push(MigratedRequest {
                    req: l.req,
                    generated: l.generated,
                    first_token: l.first_token,
                    preemptions: l.preemptions,
                });
            }
        }
        out
    }

    /// Drain *every* partially-generated live request, in ascending id
    /// order — the disaggregated prefill pool's handoff seam: once a
    /// prompt has run to first token (`generated > 0`) the request leaves
    /// the prefill replica through the KV-transfer fabric and resumes in
    /// the decode pool via [`Coordinator::submit_migrated`], keeping its
    /// generated prefix, first-token timestamp, and warm-prefix chain.
    pub fn drain_prefilled(&mut self) -> Vec<MigratedRequest> {
        let ids: Vec<crate::core::RequestId> =
            self.partial_meta().iter().map(|m| m.0).collect();
        self.drain_partials(&ids)
    }

    /// Admission-exempt intake of a migrated partially-generated request:
    /// it enters in the *preempted* phase with its prefix length intact,
    /// so the next scheduling iteration resumes it — recompute-mode
    /// re-prefill of prompt + generated prefix, the KV-reconstruction work
    /// a real migration pays on the target — rather than restarting it.
    /// Always accepts (migrations must never convert an already-admitted
    /// request into a rejection; see [`Coordinator::submit_exempt`]).
    pub fn submit_migrated(&mut self, m: MigratedRequest) -> bool {
        let generated = m.generated;
        if !self.submit_with(m.req, true) {
            return false; // unreachable: exempt submission never refuses
        }
        let l = self.live.last_mut().expect("just submitted");
        if generated > 0 {
            l.phase = Phase::Preempted;
            l.generated = generated;
        }
        l.first_token = m.first_token;
        l.preemptions = m.preemptions;
        true
    }

    /// Blocks a request needs to take its next decode token.
    fn blocks_needed(&self, l: &Live) -> usize {
        ((l.req.input_len + l.generated) as usize + 1).div_ceil(KV_BLOCK_TOKENS)
    }

    /// Drop queued requests that have exceeded the configured timeout.
    fn expire_timeouts(&mut self) {
        if self.request_timeout <= 0.0 {
            return;
        }
        let deadline = self.request_timeout;
        let now = self.now;
        let mut i = 0;
        while i < self.live.len() {
            let l = &self.live[i];
            // only never-scheduled requests time out (engine holds no state)
            if l.phase == Phase::Queued
                && l.generated == 0
                && now - l.req.arrival > deadline
            {
                let l = self.live.swap_remove(i);
                self.policy.forget(l.req.id);
                self.aborted += 1;
                self.aborted_by_class[l.req.slo.index()] += 1;
            } else {
                i += 1;
            }
        }
    }

    /// One scheduling + execution iteration. Returns false when nothing is
    /// live (caller should advance time to the next arrival).
    pub fn step(&mut self) -> anyhow::Result<bool> {
        self.expire_timeouts();
        if self.live.is_empty() {
            return Ok(false);
        }
        // --- priorities -------------------------------------------------
        let t0 = Instant::now();
        for l in &mut self.live {
            let consumed = self.cost_model.consumed(l.eff_input, l.generated);
            let view = ReqView {
                req: &l.req,
                phase: l.phase,
                generated: l.generated,
                pred_lengths: &l.pred_lengths,
                cost_dist: &l.cost_dist,
                point_pred: l.point_pred,
                rank_pred: l.rank_pred,
                consumed_cost: consumed,
                now: self.now,
            };
            l.priority = self.policy.priority(&view);
        }
        // --- selection ---------------------------------------------------
        // IO-aware preemption (paper appendix, aspect (iii)): running
        // requests get (a) a relative hysteresis margin — challengers must
        // clearly win, not tie-break-flip — and (b) a finish guard: a
        // request about to drain is never swapped (the swap IO would exceed
        // its remaining occupancy).
        let preemptive = self.policy.preemptive();
        let hyst = self.preempt_hysteresis;
        let guard = self.preempt_finish_guard;
        let eff_priority = |l: &Live| -> f64 {
            if l.phase != Phase::Running {
                return l.priority;
            }
            if guard > 0 {
                let remaining = l.point_pred - l.generated as f64;
                if remaining > 0.0 && remaining <= guard as f64 {
                    return f64::NEG_INFINITY;
                }
            }
            l.priority - l.priority.abs() * hyst
        };
        let mut order: Vec<usize> = (0..self.live.len()).collect();
        order.sort_by(|&a, &b| {
            let la = &self.live[a];
            let lb = &self.live[b];
            let ka = if !preemptive && la.phase == Phase::Running { 0 } else { 1 };
            let kb = if !preemptive && lb.phase == Phase::Running { 0 } else { 1 };
            // Non-preemptive policies order their *running* set by arrival
            // (vLLM semantics: memory-pressure eviction drops the newest
            // running request, regardless of the admission-queue metric) —
            // otherwise an SJF queue metric would silently gain SRPT-grade
            // eviction choices real engines don't give it.
            let pa = if ka == 0 { la.req.arrival } else { eff_priority(la) };
            let pb = if kb == 0 { lb.req.arrival } else { eff_priority(lb) };
            ka.cmp(&kb)
                .then(pa.partial_cmp(&pb).unwrap())
                .then(la.req.arrival.partial_cmp(&lb.req.arrival).unwrap())
                .then(la.req.id.cmp(&lb.req.id))
        });
        let max_batch = self.engine.max_batch();
        let total_blocks = self.kv.total_blocks();
        let mut planned_blocks = 0usize;
        let mut selected: Vec<usize> = Vec::new();
        for &i in &order {
            if selected.len() >= max_batch {
                break;
            }
            let need = self.blocks_needed(&self.live[i]);
            if planned_blocks + need <= total_blocks {
                planned_blocks += need;
                selected.push(i);
            }
        }
        self.sched_overhead += t0.elapsed().as_secs_f64();
        let selected_set: std::collections::HashSet<usize> = selected.iter().copied().collect();

        // --- preempt running requests that lost their slot ---------------
        for i in 0..self.live.len() {
            if self.live[i].phase == Phase::Running && !selected_set.contains(&i) {
                self.preempt(i);
            }
        }

        // --- admit: prefill / swap-in / grow ------------------------------
        // (sorted so highest priority admits first; all fit by construction)
        for &i in &selected {
            match self.live[i].phase {
                Phase::Running => {
                    let tokens = (self.live[i].req.input_len + self.live[i].generated) as usize + 1;
                    let ok = self.kv.grow_to(self.live[i].req.id, tokens);
                    debug_assert!(ok, "planned growth must fit");
                }
                Phase::Queued => self.admit_fresh(i)?,
                Phase::Preempted => self.resume(i)?,
                Phase::Done => unreachable!(),
            }
        }

        // --- decode step ---------------------------------------------------
        let mut lane_idx: Vec<usize> = selected
            .iter()
            .copied()
            .filter(|&i| self.live[i].phase == Phase::Running)
            .collect();
        lane_idx.sort_unstable();
        if lane_idx.is_empty() {
            // every selected request finished during prefill
            self.collect_finished();
            return Ok(true);
        }
        let mut lanes: Vec<LaneState> = lane_idx
            .iter()
            .map(|&i| LaneState::new(&self.live[i].req, self.live[i].generated))
            .collect();
        let resident = self.kv.resident_tokens();
        let elapsed = self.engine.decode_step(&mut lanes, resident)?;
        self.now += elapsed;
        for (k, &i) in lane_idx.iter().enumerate() {
            let lane = &lanes[k];
            let l = &mut self.live[i];
            l.generated = lane.generated;
            if lane.emitted && l.first_token.is_none() {
                l.first_token = Some(self.now);
            }
            if lane.finished {
                l.phase = Phase::Done;
            }
        }
        self.collect_finished();
        Ok(true)
    }

    fn preempt(&mut self, i: usize) {
        let id = self.live[i].req.id;
        match self.preempt_mode {
            PreemptMode::Swap => {
                let tokens = self.kv.swap_out(id);
                let dt = self.engine.swap_time(tokens);
                self.now += dt;
                self.engine.charge_swap(dt);
            }
            PreemptMode::Recompute => {
                self.kv.drop_seq(id);
                self.engine.preempt_release(id);
            }
        }
        self.live[i].phase = Phase::Preempted;
        self.live[i].preemptions += 1;
        self.preemption_count += 1;
    }

    fn admit_fresh(&mut self, i: usize) -> anyhow::Result<()> {
        let id = self.live[i].req.id;
        let tokens = self.live[i].req.input_len as usize + 1;
        let outcome = self
            .kv
            .allocate_with_prefix(id, &self.live[i].req.prefix_key, tokens);
        debug_assert!(outcome.is_some(), "planned admission must fit");
        let cached = outcome.map(|o| o.cached_tokens).unwrap_or(0) as u32;
        let pr = self.engine.prefill_cached(&self.live[i].req, cached)?;
        self.now += pr.elapsed;
        let l = &mut self.live[i];
        l.eff_input = l.req.input_len - cached.min(l.req.input_len);
        l.generated = 1; // prefill emits the first token
        l.first_token = Some(self.now);
        l.phase = if pr.finished { Phase::Done } else { Phase::Running };
        Ok(())
    }

    fn resume(&mut self, i: usize) -> anyhow::Result<()> {
        let id = self.live[i].req.id;
        match self.preempt_mode {
            PreemptMode::Swap => {
                if self.kv.residence(id) == Some(KvResidence::Swapped) {
                    match self.kv.swap_in(id) {
                        Some(tokens) => {
                            let dt = self.engine.swap_time(tokens);
                            self.now += dt;
                            self.engine.charge_swap(dt);
                            // also grow for the next token
                            let want = (self.live[i].req.input_len + self.live[i].generated)
                                as usize
                                + 1;
                            let ok = self.kv.grow_to(id, want);
                            debug_assert!(ok);
                        }
                        None => {
                            // a shared block this sequence kept on GPU was
                            // evicted while it was out: the swapped copy is
                            // incomplete, so drop it and recompute
                            self.kv.release(id);
                            self.engine.preempt_release(id);
                            self.recompute_resume(i)?;
                        }
                    }
                } else {
                    // swapped state lost (shouldn't happen) — recompute
                    self.recompute_resume(i)?;
                }
            }
            PreemptMode::Recompute => self.recompute_resume(i)?,
        }
        self.live[i].phase = Phase::Running;
        Ok(())
    }

    /// Recompute-mode resume: re-prefill prompt + generated prefix. The
    /// re-allocation goes through the prefix index, so blocks this very
    /// sequence left warm at preemption (or a sibling session kept live)
    /// shrink the recompute bill.
    fn recompute_resume(&mut self, i: usize) -> anyhow::Result<()> {
        let l = &self.live[i];
        let id = l.req.id;
        let tokens = (l.req.input_len + l.generated) as usize + 1;
        let outcome = self.kv.allocate_with_prefix(id, &l.req.prefix_key, tokens);
        debug_assert!(outcome.is_some(), "planned resume must fit");
        let cached = outcome.map(|o| o.cached_tokens).unwrap_or(0) as u32;
        // charge a prefill over the full prefix (prompt + generated)
        let mut fake = l.req.clone();
        fake.input_len += l.generated;
        let pr = self.engine.prefill_cached(&fake, cached)?;
        self.now += pr.elapsed;
        Ok(())
    }

    fn collect_finished(&mut self) {
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].phase == Phase::Done {
                let l = self.live.swap_remove(i);
                self.kv.release(l.req.id);
                self.policy.forget(l.req.id);
                // observe exactly once per request id: a request can pass
                // through a coordinator more than once (failure re-route,
                // migration), and feeding a duplicate observation would
                // double its weight in the history window
                if self.observed.insert(l.req.id) {
                    let t0 = Instant::now();
                    self.predictor.observe(&l.req, l.generated);
                    self.predict_overhead += t0.elapsed().as_secs_f64();
                    self.pred_tau.push(l.rank_pred, l.generated as f64);
                }
                let outcome = RequestOutcome {
                    id: l.req.id,
                    dataset: l.req.dataset,
                    slo: l.req.slo,
                    input_len: l.req.input_len,
                    output_len: l.generated,
                    arrival: l.req.arrival,
                    first_token: l.first_token.unwrap_or(self.now),
                    completion: self.now,
                    preemptions: l.preemptions,
                };
                if let Some(cb) = self.on_complete.as_mut() {
                    cb(&outcome, &mut self.engine);
                }
                self.engine.evict(l.req.id);
                self.outcomes.push(outcome);
            } else {
                i += 1;
            }
        }
    }

    /// Drive a full workload to completion; returns outcomes in completion
    /// order.
    pub fn run_workload(&mut self, mut requests: Vec<Request>) -> anyhow::Result<()> {
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut idx = 0;
        loop {
            // ingest everything that has arrived
            while idx < requests.len() && requests[idx].arrival <= self.now {
                let r = requests[idx].clone();
                idx += 1;
                let _ = self.submit(r); // rejections are counted internally
            }
            if self.live.is_empty() {
                if idx >= requests.len() {
                    break;
                }
                self.now = requests[idx].arrival;
                continue;
            }
            self.step()?;
        }
        Ok(())
    }

    /// Final report (filtering the first `warmup_fraction` of outcomes by
    /// arrival order so the history predictor's cold start doesn't pollute
    /// the comparison — identical treatment for every policy).
    pub fn report(&self, warmup_fraction: f64) -> RunReport {
        let mut by_arrival = self.outcomes.clone();
        by_arrival.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let skip = ((by_arrival.len() as f64) * warmup_fraction).floor() as usize;
        let measured = &by_arrival[skip.min(by_arrival.len())..];
        let mut r = RunReport::from_outcomes(measured);
        r.slo = crate::metrics::slo_class_stats(
            &self.slo.specs,
            measured,
            &by_arrival,
            &self.rejected_by_class,
            &self.aborted_by_class,
        );
        r.policy = self.policy.name().to_string();
        r.predictor = self.predictor.name().to_string();
        r.cost_model = self.cost_model.kind().name().to_string();
        r.preemptions = self.preemption_count;
        r.completed = self.outcomes.len() as u64;
        r.rejected = self.rejected;
        r.aborted = self.aborted;
        r.swap_out_events = self.kv.swap_out_events;
        r.swap_in_events = self.kv.swap_in_events;
        r.kv_peak_used_blocks = self.kv.peak_used_blocks as u64;
        r.kv_fragmentation = self.kv.fragmentation();
        r.kv_prefix_lookups = self.kv.prefix_lookups;
        r.kv_prefix_hits = self.kv.prefix_hits;
        r.kv_prefill_tokens_saved = self.kv.prefill_tokens_saved;
        r.kv_prefix_evictions = self.kv.prefix_evictions;
        r.kv_swapped_tokens_peak = self.kv.peak_swapped_tokens as u64;
        r.pred_tau = self.pred_tau.tau();
        r.pred_tau_n = self.pred_tau.len() as u64;
        let ps = self.predictor.stats();
        r.pred_threshold_hits = ps.threshold_hits;
        r.pred_fallback = ps.fallback;
        r.pred_cold = ps.cold;
        r.predict_overhead = self.predict_overhead;
        r.sched_overhead = self.sched_overhead;
        let es = self.engine.stats();
        r.busy_decode = es.busy_decode;
        r.busy_prefill = es.busy_prefill;
        r.busy_swap = es.busy_swap;
        r.decode_steps = es.decode_steps;
        r.mean_utilization = es.mean_utilization;
        r
    }
}

/// Build a simulator-backed coordinator from a config.
pub fn build_sim_coordinator(cfg: &ExperimentConfig) -> Coordinator<SimEngine> {
    build_sim_coordinator_with(cfg, cfg.engine.clone(), cfg.seed)
}

/// Build a simulator-backed coordinator with an explicit engine profile and
/// RNG seed — the cluster layer uses this to stand up heterogeneous replicas
/// (per-replica speed / batch / KV capacity) with independent policy seeds.
pub fn build_sim_coordinator_with(
    cfg: &ExperimentConfig,
    profile: crate::config::EngineProfile,
    seed: u64,
) -> Coordinator<SimEngine> {
    let engine = SimEngine::new(profile);
    let mut policy = crate::sched::make_policy_seeded(cfg, seed);
    if cfg.slo.class_aware {
        policy = Box::new(ClassAwarePolicy::new(policy, cfg.slo.clone()));
    }
    let predictor = crate::predictor::make_predictor(
        cfg.predictor,
        cfg.workload.embed_dim,
        cfg.history_capacity,
        cfg.similarity_threshold,
        seed,
    );
    let cost_model = crate::cost::make_cost_model(cfg.cost_model);
    let mut c = Coordinator::new(engine, policy, predictor, cost_model, cfg.preempt_mode);
    c.noise_mix = cfg.noise_mix;
    c.preempt_hysteresis = cfg.preempt_hysteresis;
    c.preempt_finish_guard = cfg.preempt_finish_guard;
    c.max_queue = cfg.max_queue;
    c.request_timeout = cfg.request_timeout;
    c.slo = cfg.slo.clone();
    c
}

/// Pre-warm a predictor with offline-profiled requests (the paper's
/// "public dataset" augmentation): independent draws from the same
/// workload distribution, observed with their true output lengths.
pub fn prewarm_predictor(
    predictor: &mut dyn crate::predictor::Predictor,
    cfg: &ExperimentConfig,
) {
    if cfg.history_prewarm == 0 {
        return;
    }
    let mut wl = cfg.workload.clone();
    wl.n_requests = cfg.history_prewarm;
    // the corpus was profiled offline, before serving: it reflects the
    // *pre*-drift regime (which is what makes mid-run drift adversarial
    // for the history window)
    wl.drift = Default::default();
    // distinct seed stream: the corpus is *not* the serving trace
    let corpus = WorkloadGen::new(wl, cfg.seed ^ 0x0ff1_ce).generate();
    for r in &corpus.requests {
        predictor.observe(r, r.true_output_len);
    }
}

/// Run one full simulated experiment from config: generate the workload,
/// serve it, return the report. The standard entry point used by examples
/// and every figure bench.
pub fn run_experiment(cfg: &ExperimentConfig) -> anyhow::Result<RunReport> {
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut coord = build_sim_coordinator(cfg);
    prewarm_predictor(coord.predictor.as_mut(), cfg);
    coord.run_workload(workload.requests)?;
    Ok(coord.report(cfg.warmup_fraction))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyKind, PredictorKind, WorkloadConfig};

    fn small_cfg(policy: PolicyKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = policy;
        cfg.predictor = PredictorKind::Oracle;
        cfg.workload = WorkloadConfig {
            n_requests: 120,
            rps: 10.0,
            ..WorkloadConfig::default()
        };
        cfg.warmup_fraction = 0.0;
        cfg
    }

    #[test]
    fn fcfs_serves_all_requests() {
        let cfg = small_cfg(PolicyKind::Fcfs);
        let report = run_experiment(&cfg).unwrap();
        assert_eq!(report.measured, 120);
        assert!(report.ttlt.mean > 0.0);
        assert!(report.ttft.mean > 0.0);
        assert!(report.ttft.mean <= report.ttlt.mean);
    }

    #[test]
    fn all_policies_complete_workload() {
        for kind in PolicyKind::ALL {
            let cfg = small_cfg(kind);
            let report = run_experiment(&cfg).unwrap();
            assert_eq!(report.measured, 120, "{kind:?} lost requests");
        }
    }

    #[test]
    fn output_lengths_match_ground_truth_in_sim() {
        let cfg = small_cfg(PolicyKind::SageSched);
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let truth: std::collections::BTreeMap<u64, u32> = workload
            .requests
            .iter()
            .map(|r| (r.id, r.true_output_len))
            .collect();
        let mut coord = build_sim_coordinator(&cfg);
        coord.run_workload(workload.requests).unwrap();
        for o in coord.outcomes() {
            assert_eq!(o.output_len, truth[&o.id], "req {}", o.id);
        }
    }

    #[test]
    fn completion_times_monotone_with_arrivals() {
        let cfg = small_cfg(PolicyKind::Fcfs);
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut coord = build_sim_coordinator(&cfg);
        coord.run_workload(workload.requests).unwrap();
        for o in coord.outcomes() {
            assert!(o.first_token >= o.arrival);
            assert!(o.completion >= o.first_token);
        }
    }

    #[test]
    fn srpt_beats_fcfs_under_load() {
        // the core scheduling sanity check: with full information,
        // preemptive SRPT must not be worse than FCFS on mean TTLT
        let mut fcfs_cfg = small_cfg(PolicyKind::Fcfs);
        let mut srpt_cfg = small_cfg(PolicyKind::OracleSrpt);
        for cfg in [&mut fcfs_cfg, &mut srpt_cfg] {
            cfg.workload.n_requests = 300;
            cfg.workload.rps = 14.0;
        }
        let fcfs = run_experiment(&fcfs_cfg).unwrap();
        let srpt = run_experiment(&srpt_cfg).unwrap();
        assert!(
            srpt.ttlt.mean < fcfs.ttlt.mean,
            "SRPT {} !< FCFS {}",
            srpt.ttlt.mean,
            fcfs.ttlt.mean
        );
    }

    #[test]
    fn preemption_happens_under_pressure_for_preemptive_policies() {
        let mut cfg = small_cfg(PolicyKind::OracleSrpt);
        cfg.workload.n_requests = 300;
        cfg.workload.rps = 16.0;
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut coord = build_sim_coordinator(&cfg);
        coord.run_workload(workload.requests).unwrap();
        let report = coord.report(0.0);
        assert!(report.preemptions > 0, "expected preemptions under load");
    }

    #[test]
    fn kv_is_fully_released_at_end() {
        let cfg = small_cfg(PolicyKind::SageSched);
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut coord = build_sim_coordinator(&cfg);
        coord.run_workload(workload.requests).unwrap();
        assert_eq!(coord.kv.used_blocks(), 0);
        assert_eq!(coord.live_count(), 0);
    }

    #[test]
    fn warmup_filtering_reduces_measured() {
        let cfg = small_cfg(PolicyKind::Fcfs);
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut coord = build_sim_coordinator(&cfg);
        coord.run_workload(workload.requests).unwrap();
        let full = coord.report(0.0);
        let trimmed = coord.report(0.25);
        assert_eq!(full.measured, 120);
        assert_eq!(trimmed.measured, 90);
    }

    #[test]
    fn admission_control_rejects_overflow() {
        let cfg = small_cfg(PolicyKind::Fcfs);
        let mut coord = build_sim_coordinator(&cfg);
        coord.max_queue = 5;
        let wl = WorkloadGen::new(cfg.workload.clone(), 1).generate();
        let mut accepted = 0;
        for mut r in wl.requests.into_iter().take(12) {
            r.arrival = 0.0;
            if coord.submit(r) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 5);
        assert_eq!(coord.rejected, 7);
    }

    #[test]
    fn queued_requests_time_out() {
        let cfg = small_cfg(PolicyKind::Fcfs);
        let mut coord = build_sim_coordinator(&cfg);
        coord.request_timeout = 1.0;
        let mut wl = cfg.workload.clone();
        wl.n_requests = 3;
        let reqs = WorkloadGen::new(wl, 2).generate().requests;
        for mut r in reqs {
            r.arrival = 0.0;
            coord.submit(r);
        }
        // jump time past the deadline without serving anything
        coord.advance_to(5.0);
        coord.step().unwrap();
        // all queued requests expired; none served
        assert_eq!(coord.aborted, 3);
        assert_eq!(coord.live_count(), 0);
        assert!(coord.outcomes().is_empty());
    }

    #[test]
    fn drain_queued_takes_newest_and_only_unscheduled() {
        let cfg = small_cfg(PolicyKind::Fcfs);
        let mut coord = build_sim_coordinator(&cfg);
        let mut wl = cfg.workload.clone();
        wl.n_requests = 6;
        let reqs = WorkloadGen::new(wl, 3).generate().requests;
        for (k, mut r) in reqs.into_iter().enumerate() {
            r.arrival = k as f64;
            coord.submit(r);
        }
        assert_eq!(coord.queued_count(), 6);
        let stolen = coord.drain_queued(2);
        // newest arrivals leave first; older requests keep their position
        let ids: Vec<f64> = stolen.iter().map(|r| r.arrival).collect();
        assert_eq!(ids, vec![5.0, 4.0]);
        assert_eq!(coord.live_count(), 4);
        assert!(coord.drain_queued(0).is_empty());
        // drained requests are fully forgotten: the rest still completes
        coord.run_workload(Vec::new()).unwrap();
        assert_eq!(coord.outcomes().len(), 4);
    }

    #[test]
    fn queued_meta_and_drain_ids_agree_with_drain_queued_order() {
        let cfg = small_cfg(PolicyKind::Fcfs);
        let mut coord = build_sim_coordinator(&cfg);
        let mut wl = cfg.workload.clone();
        wl.n_requests = 5;
        let reqs = WorkloadGen::new(wl, 9).generate().requests;
        for (k, mut r) in reqs.into_iter().enumerate() {
            r.arrival = k as f64;
            coord.submit(r);
        }
        let meta = coord.queued_meta();
        assert_eq!(meta.len(), 5);
        // newest first, matching drain_queued's removal order
        let arrivals: Vec<f64> = meta.iter().map(|m| m.2).collect();
        assert_eq!(arrivals, vec![4.0, 3.0, 2.0, 1.0, 0.0]);
        // drain two specific ids; unknown ids are skipped silently
        let pick = [meta[1].0, meta[3].0, 999_999];
        let moved = coord.drain_ids(&pick);
        assert_eq!(moved.len(), 2);
        assert_eq!(moved[0].id, pick[0]);
        assert_eq!(moved[1].id, pick[1]);
        assert_eq!(coord.live_count(), 3);
        // the rest still completes (policy state fully forgotten)
        coord.run_workload(Vec::new()).unwrap();
        assert_eq!(coord.outcomes().len(), 3);
    }

    #[test]
    fn drain_live_releases_everything() {
        let cfg = small_cfg(PolicyKind::SageSched);
        let mut coord = build_sim_coordinator(&cfg);
        let mut wl = cfg.workload.clone();
        wl.n_requests = 8;
        let reqs = WorkloadGen::new(wl, 4).generate().requests;
        let n = reqs.len();
        for mut r in reqs {
            r.arrival = 0.0;
            coord.submit(r);
        }
        // run a few iterations so some requests hold KV / engine state
        for _ in 0..3 {
            coord.step().unwrap();
        }
        let done = coord.outcomes().len();
        let lost = coord.drain_live();
        assert_eq!(lost.len(), n - done);
        assert_eq!(coord.live_count(), 0);
        assert_eq!(coord.kv.used_blocks(), 0, "drain must free all KV");
        assert!(coord.is_idle());
    }

    #[test]
    fn report_surfaces_rejected_and_aborted() {
        let cfg = small_cfg(PolicyKind::Fcfs);
        let mut coord = build_sim_coordinator(&cfg);
        coord.max_queue = 2;
        coord.request_timeout = 1.0;
        let mut wl = cfg.workload.clone();
        wl.n_requests = 5;
        let reqs = WorkloadGen::new(wl, 6).generate().requests;
        for mut r in reqs {
            r.arrival = 0.0;
            coord.submit(r);
        }
        coord.advance_to(10.0);
        coord.step().unwrap();
        let r = coord.report(0.0);
        assert_eq!(r.rejected, 3);
        assert_eq!(r.aborted, 2);
        assert_eq!(r.completed, 0);
        assert!(r.goodput() < 1e-9);
    }

    #[test]
    fn class_aware_admission_degrades_batch_before_interactive() {
        let mut cfg = small_cfg(PolicyKind::Fcfs);
        cfg.slo.class_aware = true;
        cfg.max_queue = 10;
        let mut coord = build_sim_coordinator(&cfg);
        let mut wl = cfg.workload.clone();
        wl.n_requests = 14;
        let reqs = WorkloadGen::new(wl, 8).generate().requests;
        let mut batch_accepted = 0;
        let mut interactive_accepted = 0;
        for (k, mut r) in reqs.into_iter().enumerate() {
            r.arrival = 0.0;
            r.slo = if k < 10 { SloClass::Batch } else { SloClass::Interactive };
            let ok = coord.submit(r);
            if ok && k < 10 {
                batch_accepted += 1;
            } else if ok {
                interactive_accepted += 1;
            }
        }
        // batch fills only ceil(10 * 0.7) = 7 slots; interactive may use
        // the reserved headroom up to the full bound of 10
        assert_eq!(batch_accepted, 7);
        assert_eq!(interactive_accepted, 3);
        assert_eq!(coord.rejected, 4);
        assert_eq!(coord.rejected_by_class, [1, 0, 3]);
        // migrations bypass admission: an exempt submission still lands
        let mut wl = cfg.workload.clone();
        wl.n_requests = 1;
        let mut extra = WorkloadGen::new(wl, 9).generate().requests.pop().unwrap();
        extra.arrival = 0.0;
        extra.slo = SloClass::Batch;
        assert!(!coord.admits(SloClass::Batch));
        assert!(coord.submit_exempt(extra));
        // class-blind: identical requests fill the whole window
        let mut blind = build_sim_coordinator(&small_cfg(PolicyKind::Fcfs));
        blind.max_queue = 10;
        let mut wl = cfg.workload.clone();
        wl.n_requests = 14;
        let reqs = WorkloadGen::new(wl, 8).generate().requests;
        let accepted = reqs
            .into_iter()
            .map(|mut r| {
                r.arrival = 0.0;
                r.slo = SloClass::Batch;
                blind.submit(r)
            })
            .filter(|&ok| ok)
            .count();
        assert_eq!(accepted, 10, "class-blind admission must ignore the class");
    }

    #[test]
    fn class_aware_serving_still_completes_everything() {
        let mut cfg = small_cfg(PolicyKind::SageSched);
        cfg.slo.class_aware = true;
        let report = run_experiment(&cfg).unwrap();
        assert_eq!(report.measured, 120);
        assert!((report.goodput() - 1.0).abs() < 1e-12);
        // per-class accounting covers every request exactly once
        let total: u64 = report.slo.values().map(|s| s.completed).sum();
        assert_eq!(total, 120);
    }

    #[test]
    fn noise_mix_still_completes() {
        let mut cfg = small_cfg(PolicyKind::SageSched);
        cfg.noise_mix = 0.2;
        let report = run_experiment(&cfg).unwrap();
        assert_eq!(report.measured, 120);
    }
}
