//! PJRT runtime: load the AOT HLO-text artifacts and execute them.
//!
//! This is the rust half of the compile bridge (see
//! `python/compile/aot.py`): `HloModuleProto::from_text_file` parses the
//! HLO **text** (the interchange format that survives the jax≥0.5 ↔
//! xla_extension 0.5.1 proto-id mismatch), `PjRtClient::cpu().compile`
//! produces an executable, and the typed wrappers below marshal
//! tokens/caches as literals. Python is never involved at runtime.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Model/artifact metadata mirrored from `python/compile/config.py`
/// (written to `artifacts/meta.json` by `aot.py`).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub bos_id: u32,
    pub eos_id: u32,
    pub pad_id: u32,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub decode_batch: usize,
    pub embed_len: usize,
}

impl ModelMeta {
    pub fn from_json(j: &Json) -> Result<ModelMeta> {
        let need = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_u64)
                .map(|v| v as usize)
                .with_context(|| format!("meta.json missing field {k}"))
        };
        Ok(ModelMeta {
            vocab: need("vocab")?,
            bos_id: need("bos_id")? as u32,
            eos_id: need("eos_id")? as u32,
            pad_id: need("pad_id")? as u32,
            d_model: need("d_model")?,
            n_layers: need("n_layers")?,
            n_heads: need("n_heads")?,
            d_head: need("d_head")?,
            max_seq: need("max_seq")?,
            prefill_len: need("prefill_len")?,
            decode_batch: need("decode_batch")?,
            embed_len: need("embed_len")?,
        })
    }

    /// Elements in one KV cache tensor `[L, B, H, S, Dh]`.
    pub fn cache_elems(&self) -> usize {
        self.n_layers * self.decode_batch * self.n_heads * self.max_seq * self.d_head
    }

    /// Elements of one lane's slice `[H, S, Dh]` within a layer.
    pub fn lane_elems(&self) -> usize {
        self.n_heads * self.max_seq * self.d_head
    }
}

/// Result of a prefill execution.
pub struct PrefillOutput {
    /// next-token logits, length `vocab`
    pub logits: Vec<f32>,
    /// per-layer K cache `[L, H, S, Dh]` flattened
    pub k: Vec<f32>,
    /// per-layer V cache `[L, H, S, Dh]` flattened
    pub v: Vec<f32>,
}

/// Result of a decode execution.
pub struct DecodeOutput {
    /// `[B, vocab]` flattened logits
    pub logits: Vec<f32>,
    /// updated caches `[L, B, H, S, Dh]` flattened
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Result of a decode execution with caches kept as literals (the
/// zero-host-copy fast path: chain these straight into the next step).
pub struct DecodeOutputLit {
    pub logits: Vec<f32>,
    pub k: xla::Literal,
    pub v: xla::Literal,
}

/// The loaded model: three compiled executables + metadata.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    meta: ModelMeta,
    prefill: xla::PjRtLoadedExecutable,
    decode: xla::PjRtLoadedExecutable,
    embed: xla::PjRtLoadedExecutable,
}

fn compile(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

impl Runtime {
    /// Load `artifacts/{prefill,decode,embed}.hlo.txt` + `meta.json`.
    pub fn load(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir: PathBuf = artifacts_dir.into();
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json (run `make artifacts`)", dir.display()))?;
        let meta = ModelMeta::from_json(
            &Json::parse(&meta_text).map_err(|e| anyhow::anyhow!("meta.json: {e}"))?,
        )?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let prefill = compile(&client, &dir.join("prefill.hlo.txt"))?;
        let decode = compile(&client, &dir.join("decode.hlo.txt"))?;
        let embed = compile(&client, &dir.join("embed.hlo.txt"))?;
        Ok(Runtime { client, meta, prefill, decode, embed })
    }

    /// Whether the artifacts directory looks loadable (used by examples and
    /// benches to fall back to the simulator gracefully).
    pub fn artifacts_present(dir: impl AsRef<Path>) -> bool {
        let d = dir.as_ref();
        ["prefill.hlo.txt", "decode.hlo.txt", "embed.hlo.txt", "meta.json"]
            .iter()
            .all(|f| d.join(f).exists())
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn tokens_literal(&self, tokens: &[u32], len: usize) -> Result<xla::Literal> {
        if tokens.len() > len {
            bail!("token sequence {} exceeds compiled length {len}", tokens.len());
        }
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(len, self.meta.pad_id as i32);
        Ok(xla::Literal::vec1(&padded).reshape(&[len as i64])?)
    }

    /// Run prefill over a (≤ prefill_len) token prompt.
    pub fn run_prefill(&self, tokens: &[u32]) -> Result<PrefillOutput> {
        let toks = self.tokens_literal(tokens, self.meta.prefill_len)?;
        let length = xla::Literal::from(tokens.len() as i32);
        let result = self.prefill.execute::<xla::Literal>(&[toks, length])?[0][0]
            .to_literal_sync()?;
        let (logits, k, v) = result.to_tuple3()?;
        Ok(PrefillOutput {
            logits: logits.to_vec::<f32>()?,
            k: k.to_vec::<f32>()?,
            v: v.to_vec::<f32>()?,
        })
    }

    /// Dimensions of one KV cache tensor `[L, B, H, S, Dh]`.
    pub fn cache_dims(&self) -> Vec<usize> {
        vec![
            self.meta.n_layers,
            self.meta.decode_batch,
            self.meta.n_heads,
            self.meta.max_seq,
            self.meta.d_head,
        ]
    }

    /// Build a cache literal from flattened host data (single copy).
    pub fn cache_literal(&self, data: &[f32]) -> Result<xla::Literal> {
        let ce = self.meta.cache_elems();
        if data.len() != ce {
            bail!("cache size mismatch: got {} want {ce}", data.len());
        }
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.cache_dims(),
            bytes,
        )?)
    }

    /// Run one decode step over the full lane batch.
    ///
    /// `tokens`/`positions` have length `decode_batch`; `k`/`v` are the
    /// flattened `[L, B, H, S, Dh]` caches. (Convenience wrapper over
    /// [`Runtime::run_decode_lit`] — the request-path hot loop uses the
    /// literal-chaining variant to avoid per-step host round-trips.)
    pub fn run_decode(
        &self,
        tokens: &[i32],
        positions: &[i32],
        k: &[f32],
        v: &[f32],
    ) -> Result<DecodeOutput> {
        let kl = self.cache_literal(k)?;
        let vl = self.cache_literal(v)?;
        let out = self.run_decode_lit(tokens, positions, &kl, &vl)?;
        Ok(DecodeOutput {
            logits: out.logits,
            k: out.k.to_vec::<f32>()?,
            v: out.v.to_vec::<f32>()?,
        })
    }

    /// Literal-chaining decode step: caches stay as XLA literals between
    /// steps, skipping ~3 large host copies per step (§Perf L3/runtime).
    pub fn run_decode_lit(
        &self,
        tokens: &[i32],
        positions: &[i32],
        k: &xla::Literal,
        v: &xla::Literal,
    ) -> Result<DecodeOutputLit> {
        let b = self.meta.decode_batch;
        if tokens.len() != b || positions.len() != b {
            bail!("decode expects exactly {b} lanes");
        }
        let toks = xla::Literal::vec1(tokens).reshape(&[b as i64])?;
        let pos = xla::Literal::vec1(positions).reshape(&[b as i64])?;
        let args: [&xla::Literal; 4] = [&toks, &pos, k, v];
        let result = self.decode.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (logits, k2, v2) = result.to_tuple3()?;
        Ok(DecodeOutputLit { logits: logits.to_vec::<f32>()?, k: k2, v: v2 })
    }

    /// Semantic embedding of a prompt (mean-pooled, L2-normalized).
    pub fn run_embed(&self, tokens: &[u32]) -> Result<Vec<f32>> {
        let n = tokens.len().min(self.meta.embed_len);
        let toks = self.tokens_literal(&tokens[..n], self.meta.embed_len)?;
        let length = xla::Literal::from(n as i32);
        let result = self.embed.execute::<xla::Literal>(&[toks, length])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// An [`crate::embedding::Embedder`] backed by the compiled embed HLO —
/// the real-model path's semantic embedder for the history predictor.
pub struct HloEmbedder<'a> {
    pub rt: &'a Runtime,
}

impl crate::embedding::Embedder for HloEmbedder<'_> {
    fn embed(&mut self, text: &str) -> crate::embedding::Embedding {
        let tokens = crate::tokenizer::encode_truncated(text, self.rt.meta.embed_len);
        match self.rt.run_embed(&tokens) {
            Ok(v) => crate::embedding::Embedding::normalize(v),
            Err(_) => crate::embedding::Embedding::normalize(vec![0.0; self.rt.meta.d_model]),
        }
    }

    fn dim(&self) -> usize {
        self.rt.meta.d_model
    }
}

// SAFETY: `Runtime` wraps raw PJRT pointers; the xla crate types are
// neither Send nor Sync by default. We move a Runtime between threads and
// share immutable references only under external serialization (the
// coordinator owns it single-threaded; the HTTP server funnels all
// execution through one serving thread), and the PJRT CPU client itself is
// thread-compatible for serialized calls.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses() {
        let j = Json::parse(
            r#"{"vocab":259,"bos_id":256,"eos_id":257,"pad_id":258,
                "d_model":64,"n_layers":2,"n_heads":4,"d_head":16,
                "max_seq":256,"prefill_len":64,"decode_batch":8,
                "embed_len":64,"d_ff":256,"kv_block":64,"seed":0}"#,
        )
        .unwrap();
        let m = ModelMeta::from_json(&j).unwrap();
        assert_eq!(m.vocab, 259);
        assert_eq!(m.cache_elems(), 2 * 8 * 4 * 256 * 16);
        assert_eq!(m.lane_elems(), 4 * 256 * 16);
    }

    #[test]
    fn meta_missing_field_errors() {
        let j = Json::parse(r#"{"vocab":259}"#).unwrap();
        assert!(ModelMeta::from_json(&j).is_err());
    }

    #[test]
    fn artifacts_present_detects_absence() {
        assert!(!Runtime::artifacts_present("/nonexistent-dir"));
    }
}
