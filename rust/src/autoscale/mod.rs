//! Elastic autoscaling: predictor-driven replica scale-out/in for the
//! event-driven cluster ([`crate::cluster::EventCluster`]).
//!
//! SageSched's thesis is that demand uncertainty should be *modeled*, not
//! averaged away. A fixed replica count does exactly that averaging at the
//! provisioning layer: under the bursty (MMPP) and diurnal arrival
//! processes of [`crate::workload::arrivals`] it either over-provisions the
//! troughs or melts down in the peaks. This module closes the loop by
//! letting a policy adjust the replica count mid-run, with a realistic
//! lifecycle — scale-out pays a provisioning delay before the cold replica
//! joins the routable set; scale-in stops routing to a victim, re-routes
//! its queued work, and retires it only once its live requests finish (no
//! request is ever stranded).
//!
//! Three policies, one per provisioning philosophy:
//!
//! * [`StepSchedule`] — scripted `time@target` steps. No feedback at all;
//!   its purpose is determinism: tests anchor conservation and lifecycle
//!   invariants on exactly-known scaling instants.
//! * [`ReactiveThreshold`] — classic watermark autoscaling (live requests
//!   per replica and KV occupancy, with a hysteresis band and a cooldown).
//!   This is the industry-default baseline: it reacts to load *after* it
//!   materializes, so bursty demand whipsaws it — exactly the behavior
//!   *Adaptively Robust LLM Inference Optimization under Prediction
//!   Uncertainty* argues provisioning must hedge against.
//! * [`UncertaintyAware`] — the paper-aligned policy: the cluster sums
//!   every in-flight request's predicted *cost distribution* (the shared
//!   predictor's [`crate::distribution::LengthDist`] pushed through the
//!   [`crate::cost::CostModel`]) and the policy provisions for a
//!   configurable quantile (default p90) of that forecast-work
//!   distribution, `W_q ≈ μ + z_q·σ` by the normal approximation for sums
//!   of independent per-request costs. Provisioning for a tail quantile
//!   rather than the mean is the capacity-planning analogue of scheduling
//!   on the Gittins index rather than the mean cost; tying the target to
//!   *work* rather than request count keeps it goodput-oriented in the
//!   sense of *SLO-Aware Scheduling for Large Language Model Inferences*
//!   (a replica-second spent on a doomed long tail is not a replica-second
//!   of goodput).
//!
//! Every policy emits a desired replica *target*; the cluster owns the
//! mechanism (spawn / drain / retire — see
//! [`AutoscaleDriver`](crate::cluster::AutoscaleDriver)) and records a
//! [`ScalingEvent`] timeline surfaced in
//! [`crate::metrics::ClusterReport`] together with `replica_seconds` and
//! goodput per replica-second — the metric a static fleet is compared on.
//!
//! Under **disaggregated serving** the driver runs one policy instance per
//! pool over pool-scoped [`AutoscaleView`]s (see
//! [`crate::cluster::disagg`]): the prefill pool is provisioned against
//! the TTFT-weighted prefill share of the forecast, the decode pool
//! against the completion-weighted decode share — the policies themselves
//! are unchanged, they just see their pool's snapshot.
//!
//! **Scale-in victim selection** is likewise the cluster's mechanism, with
//! two modes: the legacy rule drains the active replica with the fewest
//! live requests, while *migration-cost-aware* scale-in
//! (`ClusterConfig::migration_kv_per_token > 0`) scores each candidate by
//! its predicted drain cost — per partially-generated request, the cheaper
//! of waiting out a quantile of its predicted remaining cost and shipping
//! its KV — and lets the drain migrate partial work whose transfer beats
//! the wait. That prices the decision on the predicted-remaining-cost
//! *distribution* rather than a request count, in the same spirit as the
//! uncertainty-aware provisioning target above.

use crate::config::{AutoscaleConfig, AutoscaleKind, ScaleStep};
use crate::util::stats::normal_quantile_clamped;

/// Cluster snapshot handed to an [`AutoscalePolicy`] at each decision
/// point. All counts are replica states at the decision instant; the
/// backlog moments aggregate every in-flight request's predicted cost
/// distribution (mean and variance sum over independent requests).
#[derive(Clone, Debug)]
pub struct AutoscaleView {
    /// Decision instant (cluster virtual time, seconds).
    pub now: f64,
    /// Routable replicas.
    pub active: usize,
    /// Replicas spawned but still inside their provisioning delay.
    pub provisioning: usize,
    /// Failed replicas that will recover (capacity that is coming back).
    pub down: usize,
    /// Scale-in victims still finishing live work (capacity on its way out).
    pub draining: usize,
    /// Live (queued + running + preempted) requests on active replicas.
    pub total_live: usize,
    /// Never-scheduled queued requests on active replicas.
    pub total_queued: usize,
    /// Mean KV occupancy fraction over active replicas.
    pub mean_kv_occupancy: f64,
    /// Σ E[cost] over all in-flight requests (cost-model units).
    pub backlog_mean: f64,
    /// Σ Var[cost] over all in-flight requests.
    pub backlog_var: f64,
    /// Σ w·E[cost] over all in-flight requests, where w is the request's
    /// SLO-class weight (1 under class-blind serving, so this equals
    /// `backlog_mean` there). The uncertainty-aware policy provisions for
    /// this *weighted* forecast: backlog owed to high-value tiers buys
    /// proportionally more headroom.
    pub backlog_weighted_mean: f64,
    /// Σ w²·Var[cost] over all in-flight requests (the variance of the
    /// weighted sum of independent request costs).
    pub backlog_weighted_var: f64,
}

impl AutoscaleView {
    /// Capacity that is present or committed: active + provisioning + down
    /// (down replicas hold no work but will rejoin). Draining replicas are
    /// already on their way out and never count.
    pub fn present(&self) -> usize {
        self.active + self.provisioning + self.down
    }

    /// Smallest target the cluster can execute right now: scale-in can
    /// cancel every provisioning replica and drain all but one active
    /// replica, but down replicas cannot be retired. Feedback policies
    /// clamp their desired target to this floor so an unexecutable
    /// scale-in reads as a hold — and does not burn the cooldown that a
    /// later, executable decision (or a needed scale-out) would then have
    /// to wait behind.
    pub fn executable_floor(&self) -> usize {
        let retirable = self.active.saturating_sub(1) + self.provisioning;
        self.present().saturating_sub(retirable)
    }
}

/// An elastic provisioning policy: given the cluster snapshot, name the
/// desired replica count. Implementations must be deterministic given the
/// same view sequence so cluster runs stay exactly reproducible.
pub trait AutoscalePolicy: Send {
    fn kind(&self) -> AutoscaleKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Decision instants this policy needs *beyond* the periodic grid
    /// (scripted steps must fire exactly at their configured times).
    fn scheduled_times(&self) -> Vec<f64> {
        Vec::new()
    }

    /// Desired replica count, or `None` to hold. Returning
    /// `view.present()` is equivalent to holding; policies enforce their
    /// own cooldown (the scripted schedule has none).
    fn target(&mut self, view: &AutoscaleView) -> Option<usize>;
}

/// Scripted scale steps at fixed times — the deterministic test anchor.
/// The latest step with `at <= now` is in force; before the first step the
/// policy holds.
pub struct StepSchedule {
    steps: Vec<ScaleStep>,
}

impl StepSchedule {
    /// Build from (unsorted) steps; they are applied in time order. A NaN
    /// step time sorts arbitrarily here instead of panicking — it is
    /// rejected with a proper error by [`ScaleStep::validate`] before the
    /// cluster runs, but construction happens earlier and must not crash
    /// first.
    pub fn new(mut steps: Vec<ScaleStep>) -> StepSchedule {
        steps.sort_by(|a, b| {
            a.at.partial_cmp(&b.at).unwrap_or(std::cmp::Ordering::Equal)
        });
        StepSchedule { steps }
    }
}

impl AutoscalePolicy for StepSchedule {
    fn kind(&self) -> AutoscaleKind {
        AutoscaleKind::Step
    }

    fn scheduled_times(&self) -> Vec<f64> {
        self.steps.iter().map(|s| s.at).collect()
    }

    fn target(&mut self, view: &AutoscaleView) -> Option<usize> {
        self.steps
            .iter()
            .rev()
            .find(|s| s.at <= view.now)
            .map(|s| s.target.max(1))
    }
}

/// Watermark autoscaling with hysteresis + cooldown: one replica out when
/// live-per-replica or KV occupancy crosses the high watermark, one replica
/// in when both are comfortably below the low watermarks.
pub struct ReactiveThreshold {
    cfg: AutoscaleConfig,
    /// Time of the last non-hold decision (cooldown anchor).
    last_action: f64,
}

impl ReactiveThreshold {
    pub fn new(cfg: AutoscaleConfig) -> ReactiveThreshold {
        ReactiveThreshold { cfg, last_action: f64::NEG_INFINITY }
    }
}

impl AutoscalePolicy for ReactiveThreshold {
    fn kind(&self) -> AutoscaleKind {
        AutoscaleKind::Reactive
    }

    fn target(&mut self, view: &AutoscaleView) -> Option<usize> {
        if view.now - self.last_action < self.cfg.cooldown {
            return None;
        }
        let present = view.present();
        let per_replica = view.total_live as f64 / view.active.max(1) as f64;
        let desired = if per_replica > self.cfg.high_watermark
            || view.mean_kv_occupancy > self.cfg.kv_high_watermark
        {
            present + 1
        } else if per_replica < self.cfg.low_watermark
            && view.mean_kv_occupancy < self.cfg.kv_low_watermark
        {
            present.saturating_sub(1)
        } else {
            present
        };
        let desired = desired
            .clamp(self.cfg.min_replicas, self.cfg.max_replicas)
            .max(view.executable_floor());
        if desired == present {
            return None;
        }
        self.last_action = view.now;
        Some(desired)
    }
}

/// Quantile provisioning over the forecast outstanding-work distribution:
/// `target = ceil((μ + z_q·σ) / work_per_replica)` clamped to
/// `[min_replicas, max_replicas]`, where μ/σ² sum the in-flight requests'
/// predicted cost distributions (normal approximation for the sum of
/// independent costs). High-variance backlogs — exactly the heavy-tailed
/// demand the predictor flags — provision extra headroom that a mean-based
/// rule would not.
pub struct UncertaintyAware {
    cfg: AutoscaleConfig,
    /// Precomputed z-score of the configured quantile.
    z: f64,
    /// Time of the last non-hold decision (cooldown anchor).
    last_action: f64,
}

impl UncertaintyAware {
    pub fn new(cfg: AutoscaleConfig) -> UncertaintyAware {
        let z = normal_quantile_clamped(cfg.quantile);
        UncertaintyAware { cfg, z, last_action: f64::NEG_INFINITY }
    }

    /// The provisioned-for quantile of the forecast outstanding work —
    /// the SLO-*weighted* moments, so under class-aware serving a backlog
    /// dominated by high-value tiers provisions proportionally more
    /// capacity (the two coincide under class-blind serving, where every
    /// weight is 1).
    pub fn forecast_work(&self, view: &AutoscaleView) -> f64 {
        (view.backlog_weighted_mean
            + self.z * view.backlog_weighted_var.max(0.0).sqrt())
        .max(0.0)
    }
}

impl AutoscalePolicy for UncertaintyAware {
    fn kind(&self) -> AutoscaleKind {
        AutoscaleKind::UncertaintyAware
    }

    fn target(&mut self, view: &AutoscaleView) -> Option<usize> {
        if view.now - self.last_action < self.cfg.cooldown {
            return None;
        }
        let work = self.forecast_work(view);
        let desired = (work / self.cfg.work_per_replica).ceil() as usize;
        let desired = desired
            .clamp(self.cfg.min_replicas, self.cfg.max_replicas)
            .max(view.executable_floor());
        if desired == view.present() {
            return None;
        }
        self.last_action = view.now;
        Some(desired)
    }
}

/// Build the configured policy; `None` when autoscaling is off.
pub fn make_autoscaler(cfg: &AutoscaleConfig) -> Option<Box<dyn AutoscalePolicy>> {
    match cfg.kind {
        AutoscaleKind::Off => None,
        AutoscaleKind::Step => Some(Box::new(StepSchedule::new(cfg.steps.clone()))),
        AutoscaleKind::Reactive => Some(Box::new(ReactiveThreshold::new(cfg.clone()))),
        AutoscaleKind::UncertaintyAware => Some(Box::new(UncertaintyAware::new(cfg.clone()))),
    }
}

/// What happened to a replica in the scaling-event timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAction {
    /// A scale-out decision spawned this replica (provisioning begins).
    Provision,
    /// The provisioning delay elapsed; the replica joined the routable set.
    Up,
    /// A scale-in decision picked this replica: routing stops, its queued
    /// work is re-routed, live requests drain in place.
    Drain,
    /// The drained replica finished its live work and left the cluster.
    Retire,
    /// A scheduled outage took the replica down.
    Fail,
    /// The outage ended; the replica rejoined, empty.
    Recover,
}

impl ScaleAction {
    pub fn name(&self) -> &'static str {
        match self {
            ScaleAction::Provision => "provision",
            ScaleAction::Up => "up",
            ScaleAction::Drain => "drain",
            ScaleAction::Retire => "retire",
            ScaleAction::Fail => "fail",
            ScaleAction::Recover => "recover",
        }
    }
}

/// One entry of the cluster's scaling-event timeline (reported in
/// [`crate::metrics::ClusterReport`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScalingEvent {
    /// Virtual time of the transition (seconds).
    pub at: f64,
    /// Replica index the transition applies to.
    pub replica: usize,
    pub action: ScaleAction,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(now: f64, active: usize, live: usize, mu: f64, var: f64) -> AutoscaleView {
        AutoscaleView {
            now,
            active,
            provisioning: 0,
            down: 0,
            draining: 0,
            total_live: live,
            total_queued: live / 2,
            mean_kv_occupancy: 0.2,
            backlog_mean: mu,
            backlog_var: var,
            // class-blind default: weighted moments equal the raw ones
            backlog_weighted_mean: mu,
            backlog_weighted_var: var,
        }
    }

    #[test]
    fn step_schedule_applies_latest_step() {
        let mut p = StepSchedule::new(vec![
            ScaleStep { at: 40.0, target: 2 },
            ScaleStep { at: 10.0, target: 6 },
        ]);
        assert_eq!(p.target(&view(5.0, 4, 0, 0.0, 0.0)), None);
        assert_eq!(p.target(&view(10.0, 4, 0, 0.0, 0.0)), Some(6));
        assert_eq!(p.target(&view(39.0, 6, 0, 0.0, 0.0)), Some(6));
        assert_eq!(p.target(&view(40.0, 6, 0, 0.0, 0.0)), Some(2));
        assert_eq!(p.scheduled_times(), vec![10.0, 40.0]);
    }

    #[test]
    fn reactive_scales_on_watermarks_with_cooldown() {
        let cfg = AutoscaleConfig {
            kind: AutoscaleKind::Reactive,
            min_replicas: 2,
            max_replicas: 8,
            cooldown: 5.0,
            high_watermark: 8.0,
            low_watermark: 2.0,
            ..AutoscaleConfig::default()
        };
        let mut p = ReactiveThreshold::new(cfg);
        // 4 active, 40 live -> 10 per replica > 8: scale out by one
        assert_eq!(p.target(&view(0.0, 4, 40, 0.0, 0.0)), Some(5));
        // within cooldown: hold even under pressure
        assert_eq!(p.target(&view(3.0, 4, 60, 0.0, 0.0)), None);
        // after cooldown, idle fleet: scale in by one
        assert_eq!(p.target(&view(6.0, 4, 2, 0.0, 0.0)), Some(3));
        // hysteresis band between watermarks: hold (and no cooldown burn)
        assert_eq!(p.target(&view(12.0, 4, 16, 0.0, 0.0)), None);
        assert_eq!(p.target(&view(12.5, 4, 40, 0.0, 0.0)), Some(5));
        // clamps: never below min
        let mut p2 = ReactiveThreshold::new(AutoscaleConfig {
            kind: AutoscaleKind::Reactive,
            min_replicas: 2,
            max_replicas: 8,
            cooldown: 0.0,
            ..AutoscaleConfig::default()
        });
        assert_eq!(p2.target(&view(0.0, 2, 0, 0.0, 0.0)), None);
    }

    #[test]
    fn uncertainty_provisions_for_the_quantile() {
        let cfg = AutoscaleConfig {
            kind: AutoscaleKind::UncertaintyAware,
            min_replicas: 1,
            max_replicas: 16,
            cooldown: 0.0,
            quantile: 0.9,
            work_per_replica: 100.0,
            ..AutoscaleConfig::default()
        };
        let mut p = UncertaintyAware::new(cfg);
        // mean 300, sd 100: W_0.9 = 300 + 1.2816*100 ~= 428 -> 5 replicas
        let v = view(0.0, 4, 10, 300.0, 10_000.0);
        assert!((p.forecast_work(&v) - 428.155).abs() < 0.1);
        assert_eq!(p.target(&v), Some(5));
        // zero variance degrades to mean provisioning: 300/100 -> 3
        assert_eq!(p.target(&view(1.0, 4, 10, 300.0, 0.0)), Some(3));
        // empty cluster clamps to the floor
        assert_eq!(p.target(&view(2.0, 4, 0, 0.0, 0.0)), Some(1));
        // same target as present -> hold
        assert_eq!(p.target(&view(3.0, 3, 10, 300.0, 0.0)), None);
    }

    #[test]
    fn unexecutable_scale_in_holds_without_burning_cooldown() {
        // 1 active + 2 down: nothing is drainable, so a desired shrink must
        // read as a hold — and must not start the cooldown clock, or the
        // next real decision would be suppressed
        let cfg = AutoscaleConfig {
            kind: AutoscaleKind::UncertaintyAware,
            min_replicas: 1,
            max_replicas: 16,
            cooldown: 100.0,
            work_per_replica: 100.0,
            ..AutoscaleConfig::default()
        };
        let mut p = UncertaintyAware::new(cfg);
        let mut v = view(0.0, 1, 0, 0.0, 0.0);
        v.down = 2; // present 3, executable floor 3
        assert_eq!(p.target(&v), None);
        // a later executable decision still fires despite the huge cooldown
        let v2 = view(1.0, 3, 10, 1000.0, 0.0);
        assert_eq!(p.target(&v2), Some(10));
    }

    #[test]
    fn uncertainty_decisions_widen_with_variance() {
        let cfg = AutoscaleConfig {
            cooldown: 0.0,
            work_per_replica: 100.0,
            ..AutoscaleConfig::default()
        };
        let p = UncertaintyAware::new(cfg);
        let narrow = p.forecast_work(&view(0.0, 4, 10, 300.0, 100.0));
        let wide = p.forecast_work(&view(0.0, 4, 10, 300.0, 40_000.0));
        assert!(wide > narrow, "heavier tail must provision more headroom");
    }

    #[test]
    fn uncertainty_provisions_for_the_weighted_forecast() {
        // same raw backlog, but the weighted moments say the work belongs
        // to high-value tiers: the policy must provision for the weighted
        // quantile, not the raw one
        let cfg = AutoscaleConfig {
            kind: AutoscaleKind::UncertaintyAware,
            min_replicas: 1,
            max_replicas: 32,
            cooldown: 0.0,
            quantile: 0.9,
            work_per_replica: 100.0,
            ..AutoscaleConfig::default()
        };
        let mut p = UncertaintyAware::new(cfg);
        let mut v = view(0.0, 4, 10, 300.0, 0.0);
        v.backlog_weighted_mean = 1200.0; // interactive-heavy backlog, w=4
        v.backlog_weighted_var = 0.0;
        assert!((p.forecast_work(&v) - 1200.0).abs() < 1e-9);
        assert_eq!(p.target(&v), Some(12));
    }

    #[test]
    fn make_autoscaler_matches_kinds() {
        let mut cfg = AutoscaleConfig::default();
        assert!(make_autoscaler(&cfg).is_none());
        cfg.kind = AutoscaleKind::Step;
        cfg.steps = vec![ScaleStep { at: 1.0, target: 2 }];
        assert_eq!(make_autoscaler(&cfg).unwrap().kind(), AutoscaleKind::Step);
        cfg.kind = AutoscaleKind::Reactive;
        assert_eq!(
            make_autoscaler(&cfg).unwrap().kind(),
            AutoscaleKind::Reactive
        );
        cfg.kind = AutoscaleKind::UncertaintyAware;
        assert_eq!(
            make_autoscaler(&cfg).unwrap().kind(),
            AutoscaleKind::UncertaintyAware
        );
    }

    #[test]
    fn scale_action_names_are_stable() {
        for (a, n) in [
            (ScaleAction::Provision, "provision"),
            (ScaleAction::Up, "up"),
            (ScaleAction::Drain, "drain"),
            (ScaleAction::Retire, "retire"),
            (ScaleAction::Fail, "fail"),
            (ScaleAction::Recover, "recover"),
        ] {
            assert_eq!(a.name(), n);
        }
    }
}
