//! Prompt embeddings and exact nearest-neighbour search.
//!
//! The paper uses FAISS `IndexFlat` over prompt embeddings with a FIFO
//! 10k-record window; [`FlatIndex`] is the equivalent here: brute-force
//! cosine similarity over a ring buffer of normalized vectors, returning
//! all records above a similarity threshold. At the paper's window size a
//! query is a few hundred µs — matching its "<1 ms retrieval" claim.
//!
//! Two embedders feed it: [`HashEmbedder`] (hashed byte n-gram features,
//! runs anywhere, used by the simulator path) and the HLO-backed embedder
//! in [`crate::runtime`] (the L2 model's mean-pooled token embedding, used
//! by the real-model path).

use crate::util::rng::Rng;

/// An L2-normalized embedding vector.
#[derive(Clone, Debug, PartialEq)]
pub struct Embedding(pub Vec<f32>);

impl Embedding {
    /// Normalize a raw vector into an embedding; zero vectors map to a
    /// deterministic unit basis vector.
    pub fn normalize(mut v: Vec<f32>) -> Embedding {
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 1e-12 {
            for x in &mut v {
                *x /= norm;
            }
        } else if !v.is_empty() {
            v[0] = 1.0;
        }
        Embedding(v)
    }

    pub fn dim(&self) -> usize {
        self.0.len()
    }

    /// Cosine similarity (== dot product for normalized embeddings).
    pub fn cosine(&self, other: &Embedding) -> f32 {
        debug_assert_eq!(self.dim(), other.dim());
        dot(&self.0, &other.0)
    }

    /// A random unit vector (for synthetic topic directions).
    pub fn random_unit(dim: usize, rng: &mut Rng) -> Embedding {
        let v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        Embedding::normalize(v)
    }

    /// self + sigma * noise, renormalized.
    pub fn perturbed(&self, sigma: f32, rng: &mut Rng) -> Embedding {
        let v: Vec<f32> = self
            .0
            .iter()
            .map(|&x| x + sigma * rng.normal() as f32)
            .collect();
        Embedding::normalize(v)
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    // 8 independent accumulators: breaks the FP-add dependency chain so the
    // compiler can keep 2 FMA ports busy (≈3x over the naive fold; §Perf)
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    let (ah, at) = a.split_at(chunks * 8);
    let (bh, bt) = b.split_at(chunks * 8);
    for (ca, cb) in ah.chunks_exact(8).zip(bh.chunks_exact(8)) {
        for k in 0..8 {
            acc[k] += ca[k] * cb[k];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (x, y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

/// Trait for components that turn prompt text into an [`Embedding`].
pub trait Embedder: Send {
    fn embed(&mut self, text: &str) -> Embedding;
    fn dim(&self) -> usize;
}

/// Hashed byte-trigram bag-of-features embedder.
///
/// Deterministic, training-free, O(len) per prompt. Prompts sharing phrases
/// share trigram buckets, so near-duplicate prompts get high cosine — the
/// property the history predictor needs.
pub struct HashEmbedder {
    dim: usize,
}

impl HashEmbedder {
    pub fn new(dim: usize) -> HashEmbedder {
        assert!(dim >= 8);
        HashEmbedder { dim }
    }
}

impl Embedder for HashEmbedder {
    fn embed(&mut self, text: &str) -> Embedding {
        let mut v = vec![0.0f32; self.dim];
        let bytes = text.as_bytes();
        // fnv-1a over byte trigrams, signed hashing trick
        for w in bytes.windows(3.min(bytes.len().max(1))) {
            let mut h: u64 = 0xcbf29ce484222325;
            for &b in w {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            let idx = (h % self.dim as u64) as usize;
            let sign = if (h >> 63) == 0 { 1.0 } else { -1.0 };
            v[idx] += sign;
        }
        Embedding::normalize(v)
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

/// A record stored in the index.
#[derive(Clone, Debug)]
pub struct IndexRecord<T> {
    pub embedding: Embedding,
    pub payload: T,
}

/// Exact cosine-similarity index over a FIFO ring buffer — the FAISS
/// `IndexFlat` stand-in, with the paper's 10k-record sliding window.
pub struct FlatIndex<T> {
    capacity: usize,
    dim: usize,
    records: Vec<IndexRecord<T>>,
    next: usize,
    /// flattened matrix of embeddings for cache-friendly scans
    flat: Vec<f32>,
}

impl<T: Clone> FlatIndex<T> {
    pub fn new(dim: usize, capacity: usize) -> FlatIndex<T> {
        assert!(capacity > 0 && dim > 0);
        FlatIndex {
            capacity,
            dim,
            records: Vec::new(),
            next: 0,
            flat: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Insert a record, evicting the oldest once at capacity (FIFO).
    pub fn insert(&mut self, embedding: Embedding, payload: T) {
        assert_eq!(embedding.dim(), self.dim);
        if self.records.len() < self.capacity {
            self.flat.extend_from_slice(&embedding.0);
            self.records.push(IndexRecord { embedding, payload });
        } else {
            let slot = self.next;
            self.flat[slot * self.dim..(slot + 1) * self.dim]
                .copy_from_slice(&embedding.0);
            self.records[slot] = IndexRecord { embedding, payload };
            self.next = (self.next + 1) % self.capacity;
        }
    }

    /// All payloads with cosine similarity >= threshold, with similarities.
    pub fn search_threshold(&self, query: &Embedding, threshold: f32) -> Vec<(f32, &T)> {
        assert_eq!(query.dim(), self.dim);
        let mut out = Vec::new();
        for (i, rec) in self.records.iter().enumerate() {
            let s = dot(&self.flat[i * self.dim..(i + 1) * self.dim], &query.0);
            if s >= threshold {
                out.push((s, &rec.payload));
            }
        }
        out
    }

    /// Threshold search with nearest-neighbour fill: every payload with
    /// similarity >= `threshold`, plus — when those number fewer than
    /// `min_total` — the nearest below-threshold records to bring the
    /// result up to `min_total` (or the whole index if smaller). One scan
    /// serves both cases, so genuine above-threshold matches are never
    /// dropped by the fallback and the fallback costs no second pass.
    /// Threshold hits come first (scan order), fill entries follow in
    /// descending similarity; the returned count of threshold hits lets
    /// callers classify the retrieval. One pass plus a partial selection
    /// over the below-threshold remainder only when fill is needed, so
    /// the common all-hits case stays O(n) like `search_threshold`.
    pub fn search_threshold_filled(
        &self,
        query: &Embedding,
        threshold: f32,
        min_total: usize,
    ) -> (usize, Vec<(f32, &T)>) {
        assert_eq!(query.dim(), self.dim);
        let mut hits: Vec<(f32, &T)> = Vec::new();
        let mut below: Vec<(f32, &T)> = Vec::new();
        for (i, rec) in self.records.iter().enumerate() {
            let s = dot(&self.flat[i * self.dim..(i + 1) * self.dim], &query.0);
            if s >= threshold {
                hits.push((s, &rec.payload));
            } else {
                below.push((s, &rec.payload));
            }
        }
        let n_hits = hits.len();
        if n_hits < min_total && !below.is_empty() {
            let need = (min_total - n_hits).min(below.len());
            below.select_nth_unstable_by(need - 1, |a, b| b.0.partial_cmp(&a.0).unwrap());
            below.truncate(need);
            below.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            hits.extend(below);
        }
        (n_hits, hits)
    }

    /// Top-k most similar payloads (descending similarity). Uses partial
    /// selection (O(n + k log k)) rather than a full sort (§Perf).
    pub fn search_topk(&self, query: &Embedding, k: usize) -> Vec<(f32, &T)> {
        let mut all: Vec<(f32, &T)> = self
            .records
            .iter()
            .enumerate()
            .map(|(i, rec)| {
                (
                    dot(&self.flat[i * self.dim..(i + 1) * self.dim], &query.0),
                    &rec.payload,
                )
            })
            .collect();
        if all.is_empty() {
            return all;
        }
        let k = k.min(all.len());
        all.select_nth_unstable_by(k - 1, |a, b| b.0.partial_cmp(&a.0).unwrap());
        all.truncate(k);
        all.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_unit_norm() {
        let e = Embedding::normalize(vec![3.0, 4.0]);
        assert!((e.cosine(&e) - 1.0).abs() < 1e-6);
        assert!((e.0[0] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn zero_vector_normalizes_to_basis() {
        let e = Embedding::normalize(vec![0.0; 4]);
        assert_eq!(e.0[0], 1.0);
    }

    #[test]
    fn hash_embedder_similarity_ordering() {
        let mut emb = HashEmbedder::new(128);
        let a = emb.embed("please summarize this long article about birds");
        let b = emb.embed("please summarize this long article about crows");
        let c = emb.embed("write an epic poem");
        assert!(a.cosine(&b) > a.cosine(&c));
        assert!((a.cosine(&a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn hash_embedder_deterministic() {
        let mut e1 = HashEmbedder::new(64);
        let mut e2 = HashEmbedder::new(64);
        assert_eq!(e1.embed("hello world"), e2.embed("hello world"));
    }

    #[test]
    fn flat_index_threshold_search() {
        let mut idx: FlatIndex<u32> = FlatIndex::new(4, 10);
        let e1 = Embedding::normalize(vec![1.0, 0.0, 0.0, 0.0]);
        let e2 = Embedding::normalize(vec![0.0, 1.0, 0.0, 0.0]);
        let e3 = Embedding::normalize(vec![0.9, 0.1, 0.0, 0.0]);
        idx.insert(e1.clone(), 1);
        idx.insert(e2, 2);
        idx.insert(e3, 3);
        let hits = idx.search_threshold(&e1, 0.8);
        let mut ids: Vec<u32> = hits.iter().map(|(_, &p)| p).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 3]);
    }

    #[test]
    fn flat_index_fifo_eviction() {
        let mut idx: FlatIndex<u32> = FlatIndex::new(2, 3);
        let e = |x: f32, y: f32| Embedding::normalize(vec![x, y]);
        for i in 0..5 {
            idx.insert(e(1.0, i as f32), i);
        }
        assert_eq!(idx.len(), 3);
        let all = idx.search_threshold(&e(1.0, 0.0), -1.0);
        let mut ids: Vec<u32> = all.iter().map(|(_, &p)| p).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![2, 3, 4]); // 0 and 1 evicted
    }

    #[test]
    fn topk_orders_descending() {
        let mut idx: FlatIndex<u32> = FlatIndex::new(3, 10);
        let q = Embedding::normalize(vec![1.0, 0.0, 0.0]);
        idx.insert(Embedding::normalize(vec![1.0, 0.1, 0.0]), 1);
        idx.insert(Embedding::normalize(vec![0.0, 1.0, 0.0]), 2);
        idx.insert(Embedding::normalize(vec![1.0, 0.0, 0.0]), 3);
        let top = idx.search_topk(&q, 2);
        assert_eq!(*top[0].1, 3);
        assert_eq!(*top[1].1, 1);
        assert!(top[0].0 >= top[1].0);
    }

    #[test]
    fn threshold_filled_keeps_hits_and_fills_nearest() {
        let mut idx: FlatIndex<u32> = FlatIndex::new(4, 10);
        let q = Embedding::normalize(vec![1.0, 0.0, 0.0, 0.0]);
        idx.insert(Embedding::normalize(vec![1.0, 0.0, 0.0, 0.0]), 1); // hit
        idx.insert(Embedding::normalize(vec![0.9, 0.1, 0.0, 0.0]), 2); // hit
        idx.insert(Embedding::normalize(vec![0.5, 0.5, 0.0, 0.0]), 3); // near miss
        idx.insert(Embedding::normalize(vec![0.0, 1.0, 0.0, 0.0]), 4); // far
        // enough hits: no fill, no below-threshold entries
        let (n, out) = idx.search_threshold_filled(&q, 0.8, 2);
        assert_eq!(n, 2);
        let mut ids: Vec<u32> = out.iter().map(|(_, &p)| p).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
        // short of min_total: genuine hits retained, nearest miss fills
        let (n, out) = idx.search_threshold_filled(&q, 0.8, 3);
        assert_eq!(n, 2);
        let ids: Vec<u32> = out.iter().map(|(_, &p)| p).collect();
        assert_eq!(ids.len(), 3);
        assert!(ids.contains(&1) && ids.contains(&2), "threshold hits dropped");
        assert_eq!(*ids.last().unwrap(), 3, "fill must be the nearest miss");
        // min_total larger than the index: everything comes back
        let (n, out) = idx.search_threshold_filled(&q, 0.8, 99);
        assert_eq!(n, 2);
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn perturbed_similarity_decreases_with_sigma() {
        let mut rng = Rng::new(42);
        let base = Embedding::random_unit(64, &mut rng);
        let near = base.perturbed(0.05, &mut rng);
        let far = base.perturbed(1.0, &mut rng);
        assert!(base.cosine(&near) > base.cosine(&far));
        assert!(base.cosine(&near) > 0.9);
    }
}
