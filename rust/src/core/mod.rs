//! Core request/response types shared across the stack.

use crate::config::DatasetKind;
use crate::distribution::LengthDist;
use crate::embedding::Embedding;
use crate::slo::SloClass;

/// Unique request identifier (monotone per workload).
pub type RequestId = u64;

/// KV block granularity in tokens (vLLM default page size). Lives in
/// `core` because both the workload generator (prefix token-key chains are
/// per-block) and the serving stack (block math) need it without a
/// dependency cycle; [`crate::serve`] re-exports it for existing call
/// sites.
pub const KV_BLOCK_TOKENS: usize = 16;

/// An inference request as submitted to the coordinator.
///
/// `true_output_len` / `true_dist` are *hidden ground truth* produced by the
/// workload generator: the simulator uses them to decide when a request
/// finishes, the oracle predictor and figure benches use them for accuracy
/// measurement. Schedulers never read them (except the explicit oracle).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    /// Prompt text (synthetic but realistic; drives the real-model path and
    /// the hash embedder).
    pub prompt: String,
    /// Prompt token count `I`.
    pub input_len: u32,
    /// Hidden ground-truth output token count `O` (sim path).
    pub true_output_len: u32,
    /// Arrival wall/sim time in seconds.
    pub arrival: f64,
    /// Source dataset.
    pub dataset: DatasetKind,
    /// Latent topic id (workload metadata; predictors never see this).
    pub topic: usize,
    /// Precomputed semantic embedding of the prompt.
    pub embedding: Embedding,
    /// Ground-truth output-length distribution of this request's topic.
    pub true_dist: Option<LengthDist>,
    /// Latency tier this request was submitted under (stamped by the
    /// workload generator; see [`crate::slo`]).
    pub slo: SloClass,
    /// Prefix token-key chain: one key per [`KV_BLOCK_TOKENS`]-token block
    /// of this request's full token sequence (prompt + reply), identifying
    /// the block's content. Two requests whose chains agree on a leading
    /// run share that prefix verbatim (same system prompt, same
    /// conversation history), so the KV cache can serve those blocks
    /// without re-prefilling. Empty for single-shot requests — every
    /// prefix-reuse path degenerates to the private-blocks behavior.
    pub prefix_key: Vec<u64>,
}

/// Lifecycle phase of a request inside the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Arrived, waiting for first admission (no KV yet).
    Queued,
    /// Admitted and decoding (holds KV).
    Running,
    /// Preempted: KV released (recompute mode) or swapped out.
    Preempted,
    /// Finished.
    Done,
}

/// Final accounting for a completed request.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub id: RequestId,
    pub dataset: DatasetKind,
    /// Latency tier the request was served under.
    pub slo: SloClass,
    pub input_len: u32,
    pub output_len: u32,
    pub arrival: f64,
    /// Time the first output token was emitted.
    pub first_token: f64,
    /// Time the last output token was emitted.
    pub completion: f64,
    pub preemptions: u32,
}

impl RequestOutcome {
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    pub fn ttlt(&self) -> f64 {
        self.completion - self.arrival
    }

    /// TPOT as defined in the paper's statistical analyses: TTLT / output
    /// tokens.
    pub fn tpot(&self) -> f64 {
        self.ttlt() / self.output_len.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome() -> RequestOutcome {
        RequestOutcome {
            id: 1,
            dataset: DatasetKind::ShareGpt,
            slo: SloClass::Standard,
            input_len: 10,
            output_len: 20,
            arrival: 100.0,
            first_token: 101.5,
            completion: 110.0,
            preemptions: 1,
        }
    }

    #[test]
    fn latency_metrics() {
        let o = outcome();
        assert!((o.ttft() - 1.5).abs() < 1e-12);
        assert!((o.ttlt() - 10.0).abs() < 1e-12);
        assert!((o.tpot() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tpot_guards_zero_output() {
        let mut o = outcome();
        o.output_len = 0;
        assert!(o.tpot().is_finite());
    }
}
