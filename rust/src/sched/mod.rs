//! Scheduling policies (§3.3 + every baseline from §2.2 / §4.1).
//!
//! A [`Policy`] maps each live request to a scalar priority (smaller =
//! served first); the coordinator re-evaluates priorities every iteration
//! and packs the decode batch greedily under KV-memory and batch-size
//! constraints (preempting if the policy allows it). Implemented policies:
//!
//! | kind             | ordering                                   | preemptive |
//! |------------------|--------------------------------------------|-----------|
//! | `fcfs`           | arrival time (vLLM/SGLang default)          | no  |
//! | `fastserve`      | MLFQ with skip-join + quantum demotion      | yes |
//! | `ssjf`           | point output-length prediction (SJF)        | no  |
//! | `ltr`            | predicted output-length *rank* (SJF)        | no  |
//! | `trail`          | refreshed point remaining-length (SRPT)     | yes |
//! | `mean`           | E[remaining cost] of the cost distribution  | yes |
//! | `gittins`        | Gittins index, computed once at admission   | yes |
//! | `sagesched`      | Gittins index + bucketed runtime refresh    | yes |
//! | `oracle-srpt`    | true remaining cost (upper bound)           | yes |

use std::collections::HashMap;

use crate::config::PolicyKind;
use crate::core::{Phase, Request, RequestId};
use crate::distribution::LengthDist;
use crate::gittins::BucketedGittins;
use crate::util::rng::Rng;

/// Everything a policy may inspect about a live request. Ground truth
/// (`req.true_output_len`) is only read by the oracle and by the emulated
/// TRAIL/LTR predictors (see each policy's docs for the justification).
pub struct ReqView<'a> {
    pub req: &'a Request,
    pub phase: Phase,
    /// Output tokens generated so far.
    pub generated: u32,
    /// Predicted output-length distribution (from the configured predictor).
    pub pred_lengths: &'a LengthDist,
    /// Predicted service-cost distribution (cost model applied).
    pub cost_dist: &'a LengthDist,
    /// Point output-length prediction.
    pub point_pred: f64,
    /// Ranking score from the predictor's `predict_rank` seam: larger =
    /// longer expected output. Equals `point_pred` for analytic
    /// predictors; the ranking predictor supplies its learned score.
    pub rank_pred: f64,
    /// Service cost already consumed, in cost-model units.
    pub consumed_cost: f64,
    /// Current time.
    pub now: f64,
}

/// A scheduling policy.
pub trait Policy: Send {
    fn kind(&self) -> PolicyKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Priority of a request right now; smaller = higher priority.
    fn priority(&mut self, v: &ReqView) -> f64;

    /// Whether running requests may be displaced by higher-priority ones
    /// (memory-pressure eviction happens regardless, vLLM-style).
    fn preemptive(&self) -> bool {
        true
    }

    /// Called when a request completes or is aborted — drop per-id state.
    fn forget(&mut self, _id: RequestId) {}
}

// ---------------------------------------------------------------------------
// FCFS
// ---------------------------------------------------------------------------

/// First-come-first-serve: vLLM / SGLang production default.
#[derive(Default)]
pub struct FcfsPolicy;

impl Policy for FcfsPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Fcfs
    }

    fn priority(&mut self, v: &ReqView) -> f64 {
        v.req.arrival
    }

    fn preemptive(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------------------
// FastServe (MLFQ)
// ---------------------------------------------------------------------------

/// FastServe's skip-join multi-level feedback queue.
///
/// Quantum at level k is `quantum_tokens * 2^k` output tokens; a request
/// exhausting its quantum is demoted. Skip-join: long prompts enter below
/// the top queue (their "first iteration" — prefill — already exceeds the
/// top quanta). Approximates SRPT without predictions, at the price of
/// interleaving every job (the paper's Fig. 7 shows the TTLT cost).
pub struct FastServePolicy {
    pub quantum_tokens: u32,
    pub levels: usize,
    state: HashMap<RequestId, MlfqState>,
}

struct MlfqState {
    level: u32,
    served_in_level: u32,
    last_generated: u32,
}

/// MLFQ quantum at `level` on a `base`-token ladder: `base * 2^level`,
/// saturating at `u32::MAX` for deep levels instead of shifting bits out —
/// a wrapped quantum of 0 would cascade-demote every request straight to
/// the bottom queue (the old `quantum << level` did exactly that past
/// level 31, and overflowed in debug builds well before).
fn ladder_quantum(base: u32, level: u32) -> u32 {
    ((base as u64) << level.min(32)).min(u32::MAX as u64) as u32
}

impl FastServePolicy {
    pub fn new(quantum_tokens: u32, levels: usize) -> FastServePolicy {
        assert!(quantum_tokens >= 1 && levels >= 2);
        FastServePolicy { quantum_tokens, levels, state: HashMap::new() }
    }

    /// Quantum at `level` (see [`ladder_quantum`]): the single ladder both
    /// entry (skip-join) and demotion walk, so they can never diverge.
    fn quantum_at(&self, level: u32) -> u32 {
        ladder_quantum(self.quantum_tokens, level)
    }

    fn entry_level(&self, input_len: u32) -> u32 {
        // skip-join: enter the queue whose quantum covers the prompt cost
        // (prefill tokens ≈ 4x decode rate, hence the 4x headroom)
        let mut level = 0u32;
        while (level as usize) < self.levels - 1
            && input_len > self.quantum_at(level).saturating_mul(4)
        {
            level += 1;
        }
        level
    }
}

impl Policy for FastServePolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::FastServe
    }

    fn priority(&mut self, v: &ReqView) -> f64 {
        let entry = self.entry_level(v.req.input_len);
        let levels = self.levels;
        let quantum = self.quantum_tokens;
        let st = self.state.entry(v.req.id).or_insert(MlfqState {
            level: entry,
            served_in_level: 0,
            last_generated: v.generated,
        });
        // account service since last look; demote when quantum exhausted
        let newly = v.generated.saturating_sub(st.last_generated);
        st.last_generated = v.generated;
        st.served_in_level = st.served_in_level.saturating_add(newly);
        let mut q = ladder_quantum(quantum, st.level);
        while st.served_in_level >= q && (st.level as usize) < levels - 1 {
            st.served_in_level -= q;
            st.level += 1;
            q = ladder_quantum(quantum, st.level);
        }
        // order: level first, FCFS within level
        st.level as f64 * 1e9 + v.req.arrival
    }

    fn forget(&mut self, id: RequestId) {
        self.state.remove(&id);
    }
}

// ---------------------------------------------------------------------------
// SSJF
// ---------------------------------------------------------------------------

/// Speculative shortest-job-first (Qiu et al. 2024): order the queue by
/// the predictor's ranking score (`v.rank_pred`); non-preemptive. For
/// analytic predictors the score *is* the point prediction (Proxy
/// reproduces the paper's DistillBert error profile); for the ranking
/// predictor it is the learned pairwise score — SJF only consumes the
/// ordering, so any monotone score works.
#[derive(Default)]
pub struct SsjfPolicy {
    cached: HashMap<RequestId, f64>,
}

impl Policy for SsjfPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Ssjf
    }

    fn priority(&mut self, v: &ReqView) -> f64 {
        // the prediction is made once at arrival and kept stable
        *self.cached.entry(v.req.id).or_insert(v.rank_pred)
    }

    fn preemptive(&self) -> bool {
        false
    }

    fn forget(&mut self, id: RequestId) {
        self.cached.remove(&id);
    }
}

// ---------------------------------------------------------------------------
// LTR (learning-to-rank)
// ---------------------------------------------------------------------------

/// Learning-to-rank SJF (Fu et al. 2024): an OPT-125M ranker predicts the
/// *relative order* of output lengths rather than their values.
///
/// Emulation: a prompt-level ranker can at best order requests by their
/// *expected* output length (the realized length of a bimodal generation
/// is not a function of the prompt) — so the score is
/// `ln(E[O | prompt]) + N(0, σ)` with σ calibrated to the paper's
/// reported Kendall-τ ≈ 0.85 ordering quality on expectations. Only the
/// ordering of scores is consumed, matching the method.
pub struct LtrPolicy {
    rng: Rng,
    pub sigma: f64,
    cached: HashMap<RequestId, f64>,
}

impl LtrPolicy {
    pub fn new(seed: u64) -> LtrPolicy {
        LtrPolicy { rng: Rng::new(seed ^ 0x117a), sigma: 0.45, cached: HashMap::new() }
    }
}

impl Policy for LtrPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Ltr
    }

    fn priority(&mut self, v: &ReqView) -> f64 {
        let sigma = self.sigma;
        let rng = &mut self.rng;
        let expected = v
            .req
            .true_dist
            .as_ref()
            .map(|d| d.mean())
            .unwrap_or(v.req.true_output_len.max(1) as f64);
        *self
            .cached
            .entry(v.req.id)
            .or_insert_with(|| expected.max(1.0).ln() + sigma * rng.normal())
    }

    fn preemptive(&self) -> bool {
        false
    }

    fn forget(&mut self, id: RequestId) {
        self.cached.remove(&id);
    }
}

// ---------------------------------------------------------------------------
// TRAIL
// ---------------------------------------------------------------------------

/// TRAIL (Shahout et al. 2025): preemptive SRPT on a point prediction of
/// the *remaining* output length, refreshed at iteration granularity from
/// layer embeddings.
///
/// Emulation with an honest information model: at any step the embedding
/// predictor can know (a) the statistics of the remaining length *given
/// survival so far* — i.e. the conditional mean, not the realized value,
/// which for a bimodal generation is simply not encoded in the prompt —
/// and (b) a near-end signal once the reply is actually wrapping up
/// (`end_window` tokens), which hidden states do carry. Both channels get
/// lognormal noise; estimates refresh every `refresh_tokens` to capture
/// iteration-level refinement without per-step thrash.
pub struct TrailPolicy {
    rng: Rng,
    pub sigma: f64,
    pub refresh_tokens: u32,
    /// window in which the "about to end" signal becomes visible
    pub end_window: u32,
    cached: HashMap<RequestId, (u32, f64)>, // (bucket, noisy remaining)
}

impl TrailPolicy {
    pub fn new(seed: u64) -> TrailPolicy {
        TrailPolicy {
            rng: Rng::new(seed ^ 0x7ea11),
            sigma: 0.30,
            refresh_tokens: 32,
            end_window: 32,
            cached: HashMap::new(),
        }
    }

    fn estimate(&mut self, v: &ReqView) -> f64 {
        let true_rem = v.req.true_output_len.saturating_sub(v.generated).max(1) as f64;
        let base = if true_rem <= self.end_window as f64 {
            // near-end signal: embeddings reveal the reply is wrapping up
            true_rem
        } else {
            // conditional mean remaining given survival to `generated`
            v.req
                .true_dist
                .as_ref()
                .and_then(|d| d.conditional_excess(v.generated as f64))
                .map(|rem| rem.mean())
                .unwrap_or(true_rem)
        };
        base * (self.sigma * self.rng.normal()).exp()
    }
}

impl Policy for TrailPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Trail
    }

    fn priority(&mut self, v: &ReqView) -> f64 {
        let bucket = v.generated / self.refresh_tokens;
        match self.cached.get(&v.req.id) {
            Some(&(b, val)) if b == bucket => val,
            _ => {
                let val = self.estimate(v);
                self.cached.insert(v.req.id, (bucket, val));
                val
            }
        }
    }

    fn forget(&mut self, id: RequestId) {
        self.cached.remove(&id);
    }
}

// ---------------------------------------------------------------------------
// Mean-of-distribution (fig11 baseline)
// ---------------------------------------------------------------------------

/// Order by the *expected remaining cost* of the predicted cost
/// distribution (the "Mean" baseline the paper's Fig. 6/11 shows is
/// inferior to Gittins).
#[derive(Default)]
pub struct MeanCostPolicy;

impl Policy for MeanCostPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::MeanCost
    }

    fn priority(&mut self, v: &ReqView) -> f64 {
        match v.cost_dist.conditional_excess(v.consumed_cost) {
            Some(rem) => rem.mean(),
            // overdue: park behind predictable jobs (see gittins_index_at_age)
            None => v.consumed_cost + v.cost_dist.mean().max(1.0),
        }
    }
}

// ---------------------------------------------------------------------------
// Gittins (static) and SageSched (bucketed refresh)
// ---------------------------------------------------------------------------

/// Gittins-index ordering computed once at admission, never refreshed
/// (fig11's "Gittins" baseline isolating the value of runtime refresh).
#[derive(Default)]
pub struct GittinsStaticPolicy {
    cached: HashMap<RequestId, f64>,
}

impl Policy for GittinsStaticPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::GittinsStatic
    }

    fn priority(&mut self, v: &ReqView) -> f64 {
        *self
            .cached
            .entry(v.req.id)
            .or_insert_with(|| crate::gittins::gittins_index(v.cost_dist))
    }

    fn forget(&mut self, id: RequestId) {
        self.cached.remove(&id);
    }
}

/// The full SageSched policy: Gittins index over the predicted cost
/// distribution, conditioned on consumed cost, refreshed at bucket
/// boundaries (default 200 output tokens).
pub struct SageSchedPolicy {
    pub bucket_tokens: u32,
    state: HashMap<RequestId, BucketedGittins>,
    /// total number of Gittins evaluations (fig12/13 observability)
    pub refreshes: u64,
}

impl SageSchedPolicy {
    pub fn new(bucket_tokens: u32) -> SageSchedPolicy {
        SageSchedPolicy { bucket_tokens, state: HashMap::new(), refreshes: 0 }
    }
}

impl Policy for SageSchedPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::SageSched
    }

    fn priority(&mut self, v: &ReqView) -> f64 {
        let st = self
            .state
            .entry(v.req.id)
            .or_insert_with(|| BucketedGittins::new(v.cost_dist.clone(), self.bucket_tokens));
        let before = st.refresh_count;
        let g = st.index(v.generated, v.consumed_cost);
        self.refreshes += (st.refresh_count - before) as u64;
        g
    }

    fn forget(&mut self, id: RequestId) {
        self.state.remove(&id);
    }
}

// ---------------------------------------------------------------------------
// Oracle SRPT
// ---------------------------------------------------------------------------

/// True-remaining-cost SRPT: the information-theoretic upper bound all
/// prediction-based schedulers chase.
pub struct OracleSrptPolicy {
    cost: Box<dyn crate::cost::CostModel>,
}

impl OracleSrptPolicy {
    pub fn new(cost: Box<dyn crate::cost::CostModel>) -> OracleSrptPolicy {
        OracleSrptPolicy { cost }
    }
}

impl Policy for OracleSrptPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::OracleSrpt
    }

    fn priority(&mut self, v: &ReqView) -> f64 {
        let total = self.cost.cost(v.req.input_len, v.req.true_output_len as f64);
        (total - v.consumed_cost).max(0.0)
    }
}

/// Build a policy from config.
pub fn make_policy(cfg: &crate::config::ExperimentConfig) -> Box<dyn Policy> {
    make_policy_seeded(cfg, cfg.seed)
}

/// Build a policy from config with an explicit RNG seed. Multi-replica
/// clusters use this so each replica's stochastic policies (LTR / TRAIL
/// noise streams) are independent rather than lock-stepped copies.
pub fn make_policy_seeded(
    cfg: &crate::config::ExperimentConfig,
    seed: u64,
) -> Box<dyn Policy> {
    match cfg.policy {
        PolicyKind::Fcfs => Box::new(FcfsPolicy),
        PolicyKind::FastServe => {
            Box::new(FastServePolicy::new(cfg.mlfq_quantum.max(1.0) as u32, cfg.mlfq_levels))
        }
        PolicyKind::Ssjf => Box::new(SsjfPolicy::default()),
        PolicyKind::Ltr => Box::new(LtrPolicy::new(seed)),
        PolicyKind::Trail => Box::new(TrailPolicy::new(seed)),
        PolicyKind::MeanCost => Box::new(MeanCostPolicy),
        PolicyKind::GittinsStatic => Box::new(GittinsStaticPolicy::default()),
        PolicyKind::SageSched => Box::new(SageSchedPolicy::new(cfg.bucket_tokens)),
        PolicyKind::OracleSrpt => {
            Box::new(OracleSrptPolicy::new(crate::cost::make_cost_model(cfg.cost_model)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;
    use crate::cost::{CostModel, ResourceBoundCost};
    use crate::embedding::Embedding;

    fn req(id: u64, arrival: f64, input: u32, output: u32) -> Request {
        Request {
            id,
            prompt: String::new(),
            input_len: input,
            true_output_len: output,
            arrival,
            dataset: DatasetKind::ShareGpt,
            topic: 0,
            embedding: Embedding::normalize(vec![1.0]),
            true_dist: Some(LengthDist::point(output as f64)),
            slo: crate::slo::SloClass::Standard,
            prefix_key: Vec::new(),
        }
    }

    fn view<'a>(
        r: &'a Request,
        generated: u32,
        pred: &'a LengthDist,
        cost: &'a LengthDist,
    ) -> ReqView<'a> {
        let cm = ResourceBoundCost;
        ReqView {
            req: r,
            phase: Phase::Running,
            generated,
            pred_lengths: pred,
            cost_dist: cost,
            point_pred: pred.mean(),
            rank_pred: pred.mean(),
            consumed_cost: cm.consumed(r.input_len, generated),
            now: 0.0,
        }
    }

    #[test]
    fn fcfs_orders_by_arrival() {
        let mut p = FcfsPolicy;
        let (r1, r2) = (req(1, 5.0, 10, 10), req(2, 3.0, 10, 10));
        let d = LengthDist::point(10.0);
        assert!(p.priority(&view(&r2, 0, &d, &d)) < p.priority(&view(&r1, 0, &d, &d)));
        assert!(!p.preemptive());
    }

    #[test]
    fn fastserve_demotes_after_quantum() {
        let mut p = FastServePolicy::new(32, 4);
        let r = req(1, 1.0, 10, 1000);
        let d = LengthDist::point(100.0);
        let p0 = p.priority(&view(&r, 0, &d, &d));
        let p1 = p.priority(&view(&r, 10, &d, &d)); // within quantum
        assert_eq!(p0, p1);
        let p2 = p.priority(&view(&r, 40, &d, &d)); // exceeded 32
        assert!(p2 > p1 + 1e8, "expected demotion: {p1} -> {p2}");
    }

    #[test]
    fn fastserve_skip_join_long_prompts_enter_lower() {
        let p = FastServePolicy::new(32, 6);
        assert_eq!(p.entry_level(50), 0);
        assert!(p.entry_level(2000) > 0);
        assert!(p.entry_level(2000) <= 5);
    }

    #[test]
    fn fastserve_entry_and_demotion_walk_one_ladder() {
        let p = FastServePolicy::new(32, 6);
        for level in 0..6u32 {
            assert_eq!(p.quantum_at(level), 32u32 << level);
        }
        // entry level = first level whose (4x-prefill-scaled) quantum
        // covers the prompt — defined via the same quantum_at ladder
        assert_eq!(p.entry_level(32 * 4), 0);
        assert_eq!(p.entry_level(32 * 4 + 1), 1);
        assert_eq!(p.entry_level(32 * 8 + 1), 2);
    }

    #[test]
    fn fastserve_deep_ladder_saturates_instead_of_wrapping() {
        // base quantum near the u32 ceiling: level >= 1 used to wrap the
        // shifted quantum (to 0 past level 31, panicking in debug at entry)
        let mut p = FastServePolicy::new(1u32 << 31, 4);
        assert_eq!(p.quantum_at(0), 1u32 << 31);
        assert_eq!(p.quantum_at(1), u32::MAX);
        assert_eq!(p.quantum_at(40), u32::MAX);
        assert_eq!(p.entry_level(u32::MAX), 0, "saturated quantum covers any prompt");
        let r = req(1, 10, 2_000_000);
        let d = LengthDist::point(100.0);
        let p0 = p.priority(&view(&r, 0, &d, &d));
        // far below the saturated quantum: must NOT be demoted
        let p1 = p.priority(&view(&r, 1_000_000, &d, &d));
        assert_eq!(p0, p1, "spurious demotion on deep ladder");
        assert!(p1 < 1e9, "request must still sit in the top queue");
    }

    #[test]
    fn fastserve_demotes_through_deep_levels_without_overflow() {
        // tiny quantum + absurd level count: a long generation walks far
        // down the ladder; saturating arithmetic must keep quanta monotone
        let mut p = FastServePolicy::new(1, 64);
        let r = req(1, 1, 4_000);
        let d = LengthDist::point(4000.0);
        let mut last = f64::NEG_INFINITY;
        for gen in [0u32, 10, 100, 1000, 4000] {
            let pr = p.priority(&view(&r, gen, &d, &d));
            assert!(pr >= last, "priority must not improve with service");
            assert!(pr.is_finite());
            last = pr;
        }
    }

    #[test]
    fn ssjf_uses_stable_point_prediction() {
        let mut p = SsjfPolicy::default();
        let r = req(1, 0.0, 10, 100);
        let d_small = LengthDist::point(50.0);
        let first = p.priority(&view(&r, 0, &d_small, &d_small));
        // later calls keep the cached value even if the view changes
        let d_big = LengthDist::point(500.0);
        let second = p.priority(&view(&r, 5, &d_big, &d_big));
        assert_eq!(first, second);
    }

    #[test]
    fn trail_tracks_remaining_and_refreshes() {
        let mut p = TrailPolicy::new(1);
        let r = req(1, 0.0, 10, 500);
        let d = LengthDist::point(500.0);
        let early = p.priority(&view(&r, 0, &d, &d));
        let late = p.priority(&view(&r, 480, &d, &d));
        assert!(late < early, "remaining must shrink: {early} -> {late}");
        // within a refresh bucket the value is stable
        let a = p.priority(&view(&r, 100, &d, &d));
        let b = p.priority(&view(&r, 101, &d, &d));
        assert_eq!(a, b);
    }

    #[test]
    fn ltr_orders_mostly_by_true_length() {
        let mut p = LtrPolicy::new(3);
        let d = LengthDist::point(1.0);
        let mut correct = 0;
        let n = 500;
        for i in 0..n {
            let short = req(i * 2, 0.0, 10, 50);
            let long = req(i * 2 + 1, 0.0, 10, 800);
            let ps = p.priority(&view(&short, 0, &d, &d));
            let pl = p.priority(&view(&long, 0, &d, &d));
            if ps < pl {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        assert!(acc > 0.9, "pairwise ordering accuracy {acc}");
    }

    #[test]
    fn sagesched_prefers_likely_quick_finisher() {
        let mut p = SageSchedPolicy::new(200);
        let cm = ResourceBoundCost;
        let ra = req(1, 0.0, 10, 100);
        let rb = req(2, 0.0, 10, 100);
        // A: concentrated at 100; B: bimodal 10-or-400 (fig6 shape)
        let da = LengthDist::from_weighted(&[(80.0, 0.5), (120.0, 0.5)]);
        let db = LengthDist::from_weighted(&[(10.0, 0.6), (400.0, 0.4)]);
        let ca = cm.cost_dist(10, &da);
        let cb = cm.cost_dist(10, &db);
        let pa = p.priority(&view(&ra, 0, &da, &ca));
        let pb = p.priority(&view(&rb, 0, &db, &cb));
        assert!(pb < pa, "gittins must prefer the bimodal early-exit: {pb} vs {pa}");
    }

    #[test]
    fn sagesched_refresh_raises_overdue_priority_value() {
        let mut p = SageSchedPolicy::new(10);
        let cm = ResourceBoundCost;
        let r = req(1, 0.0, 10, 500);
        let d = LengthDist::from_weighted(&[(20.0, 0.7), (500.0, 0.3)]);
        let c = cm.cost_dist(10, &d);
        let v0 = view(&r, 0, &d, &c);
        let g0 = p.priority(&v0);
        // after 30 generated tokens the cheap branch is dead; index jumps
        let v1 = view(&r, 30, &d, &c);
        let g1 = p.priority(&v1);
        assert!(g1 > g0, "{g0} -> {g1}");
        assert!(p.refreshes >= 2);
    }

    #[test]
    fn gittins_static_never_refreshes() {
        let mut p = GittinsStaticPolicy::default();
        let cm = ResourceBoundCost;
        let r = req(1, 0.0, 10, 500);
        let d = LengthDist::from_weighted(&[(20.0, 0.7), (500.0, 0.3)]);
        let c = cm.cost_dist(10, &d);
        let g0 = p.priority(&view(&r, 0, &d, &c));
        let g1 = p.priority(&view(&r, 400, &d, &c));
        assert_eq!(g0, g1);
    }

    #[test]
    fn mean_policy_uses_conditional_mean() {
        let mut p = MeanCostPolicy;
        let r = req(1, 0.0, 0, 100);
        let d = LengthDist::from_weighted(&[(10.0, 0.5), (100.0, 0.5)]);
        // with zero consumed: mean = 55; after consuming 50: remaining = 50
        let v0 = ReqView {
            req: &r,
            phase: Phase::Running,
            generated: 0,
            pred_lengths: &d,
            cost_dist: &d,
            point_pred: d.mean(),
            rank_pred: d.mean(),
            consumed_cost: 0.0,
            now: 0.0,
        };
        assert!((p.priority(&v0) - 55.0).abs() < 1e-9);
        let v1 = ReqView { consumed_cost: 50.0, ..v0 };
        assert!((p.priority(&v1) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn oracle_srpt_is_exact() {
        let mut p = OracleSrptPolicy::new(Box::new(ResourceBoundCost));
        let r = req(1, 0.0, 10, 100);
        let d = LengthDist::point(1.0);
        let cm = ResourceBoundCost;
        let v = view(&r, 40, &d, &d);
        let expect = cm.cost(10, 100.0) - cm.consumed(10, 40);
        assert!((p.priority(&v) - expect).abs() < 1e-9);
    }

    #[test]
    fn make_policy_builds_all_kinds() {
        for kind in PolicyKind::ALL {
            let cfg = crate::config::ExperimentConfig {
                policy: kind,
                ..Default::default()
            };
            let p = make_policy(&cfg);
            assert_eq!(p.kind(), kind);
        }
    }
}
