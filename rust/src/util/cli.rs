//! Flag-style CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (exclude argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse("--rps 8 --policy=sagesched run");
        assert_eq!(a.f64_or("rps", 0.0), 8.0);
        assert_eq!(a.str_or("policy", ""), "sagesched");
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("--verbose --rps 4");
        assert!(a.has("verbose"));
        assert!(a.bool_or("verbose", false));
        assert!(!a.bool_or("quiet", false));
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = parse("--a --b 3");
        assert_eq!(a.get("a"), Some("true"));
        assert_eq!(a.u64_or("b", 0), 3);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.str_or("missing", "x"), "x");
    }
}
