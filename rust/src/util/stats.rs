//! Summary statistics over latency samples: mean, percentiles, histograms.

/// Summary of a sample set (all latencies in seconds unless noted).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `Default` (all zeros) for an empty slice.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let mut v: Vec<f64> = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            count: n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: percentile_sorted(&v, 0.50),
            p90: percentile_sorted(&v, 0.90),
            p99: percentile_sorted(&v, 0.99),
            max: v[n - 1],
        }
    }
}

/// Percentile (nearest-rank interpolated) over a pre-sorted slice; q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Simple fixed-width histogram with overflow bucket.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub bucket_width: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(bucket_width: f64, n_buckets: usize) -> Histogram {
        assert!(bucket_width > 0.0 && n_buckets > 0);
        Histogram { bucket_width, counts: vec![0; n_buckets + 1] }
    }

    pub fn add(&mut self, x: f64) {
        let idx = ((x / self.bucket_width) as usize).min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Fraction of mass in bucket `i`.
    pub fn frac(&self, i: usize) -> f64 {
        let t = self.total();
        if t == 0 { 0.0 } else { self.counts[i] as f64 / t as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(Summary::of(&[]), Summary::default());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 1.0), 10.0);
    }

    #[test]
    fn percentile_order_statistics() {
        let v: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert!((percentile_sorted(&v, 0.90) - 90.0).abs() < 1e-9);
        assert!((percentile_sorted(&v, 0.99) - 99.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(10.0, 3);
        for x in [0.0, 5.0, 15.0, 25.0, 99.0] {
            h.add(x);
        }
        assert_eq!(h.counts, vec![2, 1, 1, 1]);
        assert_eq!(h.total(), 5);
        assert!((h.frac(0) - 0.4).abs() < 1e-12);
    }
}
