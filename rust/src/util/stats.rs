//! Summary statistics over latency samples: mean, percentiles, histograms.

/// Summary of a sample set (all latencies in seconds unless noted).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `Default` (all zeros) for an empty slice.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let mut v: Vec<f64> = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            count: n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: percentile_sorted(&v, 0.50),
            p90: percentile_sorted(&v, 0.90),
            p99: percentile_sorted(&v, 0.99),
            max: v[n - 1],
        }
    }
}

/// Percentile (nearest-rank interpolated) over a pre-sorted slice; q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Standard-normal quantile function Φ⁻¹(p) (Acklam's rational
/// approximation, |relative error| < 1.15e-9). Used to turn "provision for
/// the p-th quantile" into a z-score for normal-approximated sums of
/// independent cost distributions. Panics outside (0, 1).
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "normal_quantile needs p in (0,1), got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Error function via the Abramowitz–Stegun 7.1.26 rational approximation
/// (|absolute error| < 1.5e-7), odd-extended to negative arguments.
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    const A1: f64 = 0.254829592;
    const A2: f64 = -0.284496736;
    const A3: f64 = 1.421413741;
    const A4: f64 = -1.453152027;
    const A5: f64 = 1.061405429;
    const P: f64 = 0.3275911;
    let t = 1.0 / (1.0 + P * x);
    let y = 1.0 - (((((A5 * t + A4) * t) + A3) * t + A2) * t + A1) * t * (-x * x).exp();
    sign * y
}

/// Standard-normal CDF Φ(x). The forward companion of [`normal_quantile`]:
/// property tests pin the two to be mutual inverses, so a regression in
/// either approximation is caught against the other.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// [`normal_quantile`] with the argument clamped into (0.001, 0.999).
/// For constructors whose quantile is already validated by every config
/// surface: a programmatically out-of-range value degrades to a
/// near-extreme quantile — and NaN to the median — instead of panicking
/// mid-construction, before the graceful validation error could be
/// produced. (`f64::clamp` propagates NaN, so it needs its own arm.)
pub fn normal_quantile_clamped(p: f64) -> f64 {
    let p = if p.is_nan() { 0.5 } else { p.clamp(0.001, 0.999) };
    normal_quantile(p)
}

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Running windowed Kendall's tau over (predicted score, actual value)
/// pairs — the rank-quality metric for output-length predictors: a
/// scheduler that orders by predicted score only needs the *ordering* to
/// be right, so tau (not MAE/W1) is the quantity that tracks scheduling
/// value. Pairs live in a FIFO ring of `cap` observations.
///
/// The concordant/discordant counts are maintained *incrementally*: each
/// push compares the new pair against the W existing ones (O(W)), and an
/// eviction subtracts exactly the relations the evicted pair once added —
/// integer counters, so the running state equals a from-scratch recount
/// bit-for-bit ([`KendallTau::tau_reference`] is the retained O(W²)
/// oracle; a regression test pins them equal at every step). `tau()`
/// itself is O(1). The previous implementation recounted all O(W²) pairs
/// per *query* on the hot completion path.
///
/// Ties in either coordinate are excluded from both the numerator and the
/// denominator (a tie carries no ordering information either way), so
/// `tau` is the fraction of decisive pairs ordered correctly, rescaled to
/// [-1, 1]. Fewer than 2 decisive pairs yields 0.
#[derive(Clone, Debug)]
pub struct KendallTau {
    window: std::collections::VecDeque<(f64, f64)>,
    cap: usize,
    concordant: i64,
    discordant: i64,
}

impl KendallTau {
    pub fn new(cap: usize) -> KendallTau {
        assert!(cap >= 2);
        KendallTau {
            window: std::collections::VecDeque::with_capacity(cap),
            cap,
            concordant: 0,
            discordant: 0,
        }
    }

    /// +1 concordant, -1 discordant, 0 tied — symmetric in its arguments,
    /// so subtracting an evicted pair's relations undoes exactly what its
    /// insertion added.
    fn relation(a: (f64, f64), b: (f64, f64)) -> i64 {
        let dp = a.0 - b.0;
        let da = a.1 - b.1;
        if dp == 0.0 || da == 0.0 {
            0
        } else if (dp > 0.0) == (da > 0.0) {
            1
        } else {
            -1
        }
    }

    /// Record one (predicted score, actual value) observation, evicting
    /// the oldest once the window is full.
    pub fn push(&mut self, pred: f64, actual: f64) {
        if !pred.is_finite() || !actual.is_finite() {
            return;
        }
        if self.window.len() == self.cap {
            let evicted = self.window.pop_front().expect("cap >= 2, so non-empty");
            for &p in &self.window {
                match Self::relation(evicted, p) {
                    1 => self.concordant -= 1,
                    -1 => self.discordant -= 1,
                    _ => {}
                }
            }
        }
        let fresh = (pred, actual);
        for &p in &self.window {
            match Self::relation(fresh, p) {
                1 => self.concordant += 1,
                -1 => self.discordant += 1,
                _ => {}
            }
        }
        self.window.push_back(fresh);
    }

    /// Number of observations currently in the window.
    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    /// Kendall's tau over the current window; 0.0 when fewer than 2
    /// decisive (untied) pairs exist. O(1) off the running counters.
    pub fn tau(&self) -> f64 {
        let decisive = self.concordant + self.discordant;
        if decisive < 2 {
            return 0.0;
        }
        (self.concordant - self.discordant) as f64 / decisive as f64
    }

    /// The retained O(W²) recount — the oracle the incremental counters
    /// are pinned against (regression tests assert `tau()` equals this
    /// bit-for-bit at every step).
    pub fn tau_reference(&self) -> f64 {
        let v: Vec<(f64, f64)> = self.window.iter().copied().collect();
        let mut concordant = 0i64;
        let mut discordant = 0i64;
        for i in 0..v.len() {
            for j in (i + 1)..v.len() {
                match Self::relation(v[i], v[j]) {
                    1 => concordant += 1,
                    -1 => discordant += 1,
                    _ => {}
                }
            }
        }
        let decisive = concordant + discordant;
        if decisive < 2 {
            return 0.0;
        }
        (concordant - discordant) as f64 / decisive as f64
    }
}

/// Simple fixed-width histogram with overflow bucket.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub bucket_width: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(bucket_width: f64, n_buckets: usize) -> Histogram {
        assert!(bucket_width > 0.0 && n_buckets > 0);
        Histogram { bucket_width, counts: vec![0; n_buckets + 1] }
    }

    pub fn add(&mut self, x: f64) {
        let idx = ((x / self.bucket_width) as usize).min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Fraction of mass in bucket `i`.
    pub fn frac(&self, i: usize) -> f64 {
        let t = self.total();
        if t == 0 { 0.0 } else { self.counts[i] as f64 / t as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(Summary::of(&[]), Summary::default());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 1.0), 10.0);
    }

    #[test]
    fn percentile_order_statistics() {
        let v: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert!((percentile_sorted(&v, 0.90) - 90.0).abs() < 1e-9);
        assert!((percentile_sorted(&v, 0.99) - 99.0).abs() < 1e-9);
    }

    #[test]
    fn normal_quantile_known_values() {
        assert!(normal_quantile(0.5).abs() < 1e-9);
        assert!((normal_quantile(0.9) - 1.2815515655).abs() < 1e-6);
        assert!((normal_quantile(0.975) - 1.9599639845).abs() < 1e-6);
        // symmetry and the tail branches
        for p in [0.001, 0.01, 0.1, 0.3] {
            let lo = normal_quantile(p);
            let hi = normal_quantile(1.0 - p);
            assert!((lo + hi).abs() < 1e-6, "asymmetric at p={p}");
            assert!(lo < 0.0 && hi > 0.0);
        }
        // monotone
        let mut prev = f64::NEG_INFINITY;
        for i in 1..100 {
            let z = normal_quantile(i as f64 / 100.0);
            assert!(z > prev);
            prev = z;
        }
    }

    #[test]
    fn normal_cdf_known_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.2815515655) - 0.9).abs() < 1e-4);
        assert!((normal_cdf(-1.9599639845) - 0.025).abs() < 1e-4);
        assert!(normal_cdf(-8.0) < 1e-9);
        assert!(normal_cdf(8.0) > 1.0 - 1e-9);
    }

    #[test]
    fn kendall_tau_perfect_and_inverted() {
        let mut t = KendallTau::new(64);
        for i in 0..20 {
            t.push(i as f64, (i * 3) as f64);
        }
        assert!((t.tau() - 1.0).abs() < 1e-12, "monotone ordering must give tau=1");
        let mut t = KendallTau::new(64);
        for i in 0..20 {
            t.push(i as f64, -(i as f64));
        }
        assert!((t.tau() + 1.0).abs() < 1e-12, "inverted ordering must give tau=-1");
    }

    #[test]
    fn kendall_tau_ties_are_excluded() {
        let mut t = KendallTau::new(16);
        // constant prediction: every pair tied in pred => no decisive pairs
        for i in 0..10 {
            t.push(1.0, i as f64);
        }
        assert_eq!(t.tau(), 0.0);
        // one decisive pair is still below the 2-pair floor
        let mut t = KendallTau::new(16);
        t.push(1.0, 1.0);
        t.push(2.0, 2.0);
        assert_eq!(t.tau(), 0.0);
    }

    #[test]
    fn kendall_tau_window_evicts_oldest() {
        let mut t = KendallTau::new(8);
        // fill with inverted pairs, then overwrite with concordant ones:
        // once the window has turned over, tau must reflect only the new regime
        for i in 0..8 {
            t.push(i as f64, -(i as f64));
        }
        assert!(t.tau() < -0.99);
        for i in 0..8 {
            t.push(i as f64, i as f64);
        }
        assert_eq!(t.len(), 8);
        assert!(t.tau() > 0.99, "stale inverted pairs must be evicted");
    }

    #[test]
    fn kendall_tau_ignores_non_finite() {
        let mut t = KendallTau::new(8);
        t.push(f64::NAN, 1.0);
        t.push(1.0, f64::INFINITY);
        assert!(t.is_empty());
    }

    #[test]
    fn kendall_tau_incremental_matches_reference_exactly() {
        // random sequences heavy in ties and negatives, with full window
        // turnover: the incremental counters must equal the O(W²) recount
        // bit-for-bit at every single step
        let mut rng = crate::util::rng::Rng::new(0x7A0);
        let mut t = KendallTau::new(16);
        for _ in 0..100 {
            // small integer grid so pred/actual ties are frequent
            let pred = rng.below(8) as f64 - 3.0;
            let actual = rng.below(8) as f64 - 3.0;
            t.push(pred, actual);
            assert_eq!(t.tau().to_bits(), t.tau_reference().to_bits());
        }
        assert_eq!(t.len(), 16);
    }

    #[test]
    fn kendall_tau_pinned_values() {
        // pinned by hand: pairs (1,2) (2,1) (3,3) — relations
        // (1,2)-(2,1) discordant, (1,2)-(3,3) concordant,
        // (2,1)-(3,3) concordant => tau = (2-1)/3
        let mut t = KendallTau::new(8);
        t.push(1.0, 2.0);
        t.push(2.0, 1.0);
        t.push(3.0, 3.0);
        assert!((t.tau() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(t.tau().to_bits(), t.tau_reference().to_bits());
        // a tie in pred drops the pair from both counts
        t.push(3.0, 0.0); // ties with (3,3) in pred; decisive vs the rest
        assert_eq!(t.tau().to_bits(), t.tau_reference().to_bits());
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(10.0, 3);
        for x in [0.0, 5.0, 15.0, 25.0, 99.0] {
            h.add(x);
        }
        assert_eq!(h.counts, vec![2, 1, 1, 1]);
        assert_eq!(h.total(), 5);
        assert!((h.frac(0) - 0.4).abs() < 1e-12);
    }
}
