//! In-tree substrate utilities.
//!
//! The build is fully offline (only the crates vendored for the PJRT bridge
//! are available), so the usual ecosystem crates are re-implemented here at
//! the size this project needs: a seedable PCG64 RNG with the distributions
//! the workload generator uses ([`rng`]), summary statistics ([`stats`]), a
//! small JSON value/parser/writer ([`json`]) for configs, traces and bench
//! output, and a flag-style CLI argument parser ([`cli`]).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
