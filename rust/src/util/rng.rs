//! Seedable PCG64 random number generator + the sampling helpers the
//! workload generator and simulator need (uniform, normal, lognormal,
//! exponential, Poisson, categorical).
//!
//! PCG-XSL-RR 128/64 (O'Neill 2014). Deterministic across platforms for a
//! given seed — experiment runs are exactly reproducible, which the figure
//! benches rely on.

/// PCG64 generator (XSL-RR 128/64 variant).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: ((seed as u128) << 1) | 1,
            spare_normal: None,
        };
        rng.state = rng
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(rng.inc)
            .wrapping_add(0x9e3779b97f4a7c15_u128 ^ ((seed as u128) << 64 | seed as u128));
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream (for per-request / per-node RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Lemire rejection-free-ish: acceptably unbiased for our n << 2^64
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as u32
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Poisson-distributed count with the given mean (Knuth for small
    /// means, normal approximation above 64 — adequate for workload gen).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        if mean > 64.0 {
            let v = self.normal_ms(mean, mean.sqrt()).round();
            return if v < 0.0 { 0 } else { v as u64 };
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Sample an index from unnormalized weights. Panics on empty/zero-sum.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with non-positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(4);
        let n = 50_000;
        let lambda = 4.0;
        let mean: f64 = (0..n).map(|_| rng.exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut rng = Rng::new(5);
        for lam in [0.5, 8.0, 120.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| rng.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lam).abs() < lam.max(1.0) * 0.05,
                "lam={lam} mean={mean}"
            );
        }
    }

    #[test]
    fn categorical_frequencies() {
        let mut rng = Rng::new(6);
        let w = [1.0, 3.0];
        let mut c = [0usize; 2];
        for _ in 0..40_000 {
            c[rng.categorical(&w)] += 1;
        }
        let frac = c[1] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(10);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
