//! Minimal JSON value, parser, and writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic escapes (`\u` beyond BMP is
//! passed through decoded); numbers are f64. Used for configs, run reports,
//! trace files and the HTTP API.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // --- accessors ---

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 { Some(x as u64) } else { None }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` chained with f64 extraction, with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len()
                        && (self.bytes[self.pos] & 0xC0) == 0x80
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    /// Compact single-line serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,"x\n"],"c":{"d":-2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_whitespace_and_empty() {
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{1:2}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] junk").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn numbers_all_forms() {
        for (s, want) in [("0", 0.0), ("-0.5", -0.5), ("1e3", 1000.0), ("2.5E-1", 0.25)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want), "{s}");
        }
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.25).to_string(), "3.25");
    }

    #[test]
    fn as_u64_rejects_negative_and_fractional() {
        assert_eq!(Json::num(4.0).as_u64(), Some(4));
        assert_eq!(Json::num(-1.0).as_u64(), None);
        assert_eq!(Json::num(1.5).as_u64(), None);
    }
}
