//! Micro-benchmarks for the L3 hot paths (hand-rolled harness — criterion
//! is unavailable offline). These are the §Perf instruments: run before and
//! after each optimization and record deltas in EXPERIMENTS.md.
//!
//! ```text
//! cargo bench --bench micro             # all
//! cargo bench --bench micro -- gittins  # filter by substring
//! ```

mod common;

use common::{fmt_ns, time_ns};

use sagesched::config::{ExperimentConfig, PolicyKind, PredictorKind, WorkloadConfig};
use sagesched::cost::{CostModel, ResourceBoundCost};
use sagesched::distribution::LengthDist;
use sagesched::embedding::{Embedder, Embedding, FlatIndex, HashEmbedder};
use sagesched::engine::{Engine, LaneState, SimEngine};
use sagesched::gittins::{gittins_index, gittins_index_at_age};
use sagesched::kvcache::KvManager;
use sagesched::predictor::{HistoryPredictor, Predictor};
use sagesched::serve::{build_sim_coordinator, prewarm_predictor};
use sagesched::util::json::Json;
use sagesched::util::rng::Rng;
use sagesched::workload::WorkloadGen;

struct Bench {
    filter: Vec<String>,
    results: Vec<(String, f64)>,
}

impl Bench {
    fn run(&mut self, name: &str, warmup: usize, iters: usize, f: impl FnMut()) {
        if !self.filter.is_empty()
            && !self.filter.iter().any(|w| name.contains(w.as_str()))
        {
            return;
        }
        let ns = time_ns(f, warmup, iters);
        println!("{name:<46} {:>12}", fmt_ns(ns));
        self.results.push((name.to_string(), ns));
    }
}

fn dist_k(k: usize) -> LengthDist {
    let mut rng = Rng::new(1);
    let samples: Vec<f64> = (0..4 * k).map(|_| rng.lognormal(5.0, 0.8)).collect();
    LengthDist::from_samples(&samples).compress(k)
}

fn main() {
    let filter: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--"))
        .collect();
    let mut b = Bench { filter, results: Vec::new() };
    println!("{:-<60}", "");

    // --- gittins -----------------------------------------------------------
    let d64 = dist_k(64);
    let d16 = dist_k(16);
    b.run("gittins_index k=16", 100, 20_000, || {
        std::hint::black_box(gittins_index(&d16));
    });
    b.run("gittins_index k=64", 100, 20_000, || {
        std::hint::black_box(gittins_index(&d64));
    });
    b.run("gittins_index_at_age k=64 (cond+eval)", 100, 10_000, || {
        std::hint::black_box(gittins_index_at_age(&d64, 2000.0));
    });

    // --- distribution ops ---------------------------------------------------
    let samples: Vec<f64> = {
        let mut rng = Rng::new(2);
        (0..200).map(|_| rng.lognormal(5.0, 0.7)).collect()
    };
    b.run("LengthDist::from_samples n=200 + compress64", 50, 5_000, || {
        std::hint::black_box(LengthDist::from_samples(&samples).compress(64));
    });
    let other = dist_k(64);
    b.run("w1_distance k=64", 50, 10_000, || {
        std::hint::black_box(d64.w1_distance(&other));
    });

    // --- cost model ----------------------------------------------------------
    let cm = ResourceBoundCost;
    b.run("cost_dist transform k=64", 100, 20_000, || {
        std::hint::black_box(cm.cost_dist(512, &d64));
    });

    // --- embedding + index ----------------------------------------------------
    let mut emb = HashEmbedder::new(64);
    let prompt = "please summarize the following long article about glaciers";
    b.run("hash_embed 60-char prompt dim=64", 100, 20_000, || {
        std::hint::black_box(emb.embed(prompt));
    });
    let mut index: FlatIndex<u32> = FlatIndex::new(64, 10_000);
    let mut rng = Rng::new(3);
    for i in 0..10_000 {
        index.insert(Embedding::random_unit(64, &mut rng), i);
    }
    let query = Embedding::random_unit(64, &mut rng);
    b.run("flat_index search 10k x 64d (paper window)", 20, 2_000, || {
        std::hint::black_box(index.search_threshold(&query, 0.8));
    });
    b.run("flat_index top-5 10k x 64d", 20, 1_000, || {
        std::hint::black_box(index.search_topk(&query, 5));
    });

    // --- history predictor end-to-end -----------------------------------------
    let cfg = ExperimentConfig::default();
    let mut predictor = HistoryPredictor::new(64, 10_000, 0.8);
    {
        let mut c2 = cfg.clone();
        c2.history_prewarm = 10_000;
        prewarm_predictor(&mut predictor, &c2);
    }
    let mut wl = WorkloadConfig::default();
    wl.n_requests = 64;
    let probes = WorkloadGen::new(wl, 5).generate();
    let mut pi = 0;
    b.run("history_predict (10k window, full pipeline)", 20, 2_000, || {
        let r = &probes.requests[pi % probes.requests.len()];
        pi += 1;
        std::hint::black_box(predictor.predict(r));
    });

    // --- kv manager -------------------------------------------------------------
    b.run("kv grow+release cycle (64 seqs)", 20, 2_000, || {
        let mut kv = KvManager::new(100_000, 16);
        for id in 0..64u64 {
            kv.grow_to(id, 600);
        }
        for id in 0..64u64 {
            kv.release(id);
        }
        std::hint::black_box(kv.free_blocks());
    });

    // --- sim engine step ----------------------------------------------------------
    let mut engine = SimEngine::new(sagesched::config::EngineProfile::a40_llama8b());
    let req = {
        let mut wl = WorkloadConfig::default();
        wl.n_requests = 1;
        WorkloadGen::new(wl, 6).generate().requests.pop().unwrap()
    };
    let mut lanes: Vec<LaneState> = (0..64).map(|_| LaneState::new(&req, 1)).collect();
    b.run("sim decode_step batch=64", 100, 20_000, || {
        for l in lanes.iter_mut() {
            l.generated = 1;
            l.finished = false;
        }
        std::hint::black_box(engine.decode_step(&mut lanes, 30_000).unwrap());
    });

    // --- coordinator scheduling iteration ------------------------------------------
    let mut cfg2 = ExperimentConfig::default();
    cfg2.policy = PolicyKind::SageSched;
    cfg2.predictor = PredictorKind::Oracle;
    cfg2.workload.n_requests = 400;
    cfg2.workload.rps = 1e9; // all arrive at once: max queue depth
    let workload = WorkloadGen::new(cfg2.workload.clone(), 7).generate();
    let mut coord = build_sim_coordinator(&cfg2);
    for r in workload.requests {
        coord.submit(r);
    }
    b.run("coordinator step, 400 live (sagesched)", 5, 200, || {
        std::hint::black_box(coord.step().unwrap());
    });

    // --- json ---------------------------------------------------------------------
    let doc = r#"{"policy":"sagesched","ttlt":{"mean":12.5,"p99":40.1},"arr":[1,2,3,4,5]}"#;
    b.run("json parse small report", 100, 50_000, || {
        std::hint::black_box(Json::parse(doc).unwrap());
    });

    println!("{:-<60}", "");
    println!("{} benchmarks", b.results.len());
}
