//! Raw-speed harness for the event kernel at fleet scale: a large
//! MMPP + failures + domain outage + autoscale + sessions baseline plus a
//! session-heavy disaggregated cache-affinity scenario, each run under the
//! incremental router indexes and (optionally) the retained full-rescan
//! oracle, with byte-identical-report gates on both axes.
//!
//! Usage:
//!   cargo bench --bench cluster_scale                 # full 1,000-replica run
//!   cargo bench --bench cluster_scale -- --smoke      # CI-sized config
//!   cargo bench --bench cluster_scale -- --skip-oracle
//!   cargo bench --bench cluster_scale -- --out path/to/BENCH_cluster.json
//!
//! The harness exits non-zero if any gate fails:
//!   1. run-twice: two indexed runs must serialize byte-identically,
//!      fast-path counters included (catches nondeterminism creep before
//!      it corrupts an A/B number);
//!   2. oracle: the indexed report must equal the full-rescan report byte
//!      for byte outside the fast-path accounting block — the one section
//!      designed to differ between modes (the ≥10x speedup claim is only
//!      meaningful if the fast path computes the *same* simulation);
//!   3. hit-rate floor (smoke): the baseline scenario's combined fast-path
//!      hit rate must stay above [`SMOKE_HIT_RATE_FLOOR`], so a regression
//!      that silently diverts dispatches onto the rescan path fails CI
//!      even though the reports still agree.
//!
//! Results land in `BENCH_cluster.json` (smoke mode writes under
//! `bench_out/` so a CI run never clobbers the checked-in baseline).

mod common;

use std::time::Instant;

use sagesched::cluster::EventCluster;
use sagesched::config::{
    ArrivalKind, AutoscaleKind, DomainFailureEvent, ExperimentConfig,
    FailureDomain, FailureEvent, PolicyKind, PoolRole, PredictorKind, RouterKind,
};
use sagesched::metrics::{peak_rss_mb, ClusterReport, FastPathStats, PerfStats};
use sagesched::util::json::Json;
use sagesched::workload::WorkloadGen;

/// Minimum combined fast-path hit rate the smoke baseline must sustain.
/// The baseline routes through quantile-cost, whose declared fast path is
/// a pure index lookup — in practice nearly every dispatch hits, so 0.5
/// leaves head-room for scope-empty windows during outages while still
/// catching any change that diverts dispatch wholesale onto the rescan.
const SMOKE_HIT_RATE_FLOOR: f64 = 0.5;

/// Serialize a report with the wallclock-measured overhead fields zeroed —
/// the only nondeterministic numbers in it (same convention as the golden
/// test in `tests/slo.rs`). `strip_fastpath` additionally drops the
/// per-scope fast-path counters for cross-mode comparisons.
fn deterministic_json(mut r: ClusterReport, strip_fastpath: bool) -> String {
    r.aggregate.predict_overhead = 0.0;
    r.aggregate.sched_overhead = 0.0;
    for pr in &mut r.per_replica {
        pr.predict_overhead = 0.0;
        pr.sched_overhead = 0.0;
    }
    if strip_fastpath {
        r.fastpath = FastPathStats::default();
    }
    r.to_json().to_string()
}

/// The campaign scenario: every hot path at once. Smoke mode shrinks the
/// fleet and request count to CI scale but keeps every feature switched on
/// so the same code paths are exercised.
fn scenario(smoke: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    // the cheap distribution head: the bench measures the event kernel,
    // not history-predictor lookups
    cfg.predictor = PredictorKind::Proxy;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0;
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.workload.sessions.enabled = true;
    cfg.cluster.router = RouterKind::QuantileCost;
    cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.autoscale.cooldown = 2.0;
    cfg.cluster.autoscale.provision_delay = 1.0;
    cfg.cluster.autoscale.work_per_replica = 5.0e5;
    if smoke {
        cfg.cluster.replicas = 8;
        cfg.workload.n_requests = 600;
        cfg.workload.rps = 40.0;
        cfg.cluster.autoscale.min_replicas = 6;
        cfg.cluster.autoscale.max_replicas = 12;
        cfg.cluster.failures =
            vec![FailureEvent { replica: 1, at: 3.0, duration: 2.0 }];
    } else {
        cfg.cluster.replicas = 1000;
        cfg.workload.n_requests = 1_000_000;
        cfg.workload.rps = 2000.0;
        cfg.cluster.autoscale.min_replicas = 900;
        cfg.cluster.autoscale.max_replicas = 1100;
        // individual outages plus a 20-replica rack outage, windows
        // disjoint (overlapping windows on one replica are a config error)
        cfg.cluster.failures = vec![
            FailureEvent { replica: 3, at: 60.0, duration: 30.0 },
            FailureEvent { replica: 17, at: 180.0, duration: 45.0 },
        ];
        cfg.cluster.failure_domains = vec![FailureDomain {
            name: "rack0".to_string(),
            replicas: (0..20).collect(),
        }];
        cfg.cluster.domain_failures =
            vec![DomainFailureEvent { domain: 0, at: 300.0, duration: 20.0 }];
    }
    cfg
}

/// The tentpole's own scenario: session-heavy traffic over disaggregated
/// pools with the cache-affinity router, so the shortlist + dominance-bound
/// fast path and the decode-scope index twin carry the dispatch load.
fn scenario_session_disagg(smoke: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    cfg.predictor = PredictorKind::Proxy;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0;
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.workload.sessions.enabled = true;
    cfg.workload.sessions.prefix_share = 0.8;
    cfg.cluster.router = RouterKind::CacheAffinity;
    cfg.cluster.pools = vec![PoolRole::Prefill, PoolRole::Decode];
    if smoke {
        cfg.cluster.replicas = 6;
        cfg.workload.n_requests = 400;
        cfg.workload.rps = 30.0;
    } else {
        cfg.cluster.replicas = 400;
        cfg.workload.n_requests = 300_000;
        cfg.workload.rps = 800.0;
    }
    cfg
}

struct ModeRun {
    stats: PerfStats,
    /// Report with fast-path counters kept (run-twice determinism gate).
    report_full: String,
    /// Report with fast-path counters stripped (cross-mode oracle gate).
    report_stripped: String,
    /// Combined fast-path hit rate over every dispatch scope.
    hit_rate: f64,
}

/// One full run of the scenario with the index fast paths on or off,
/// timing each phase separately.
fn run_mode(cfg: &ExperimentConfig, use_indexes: bool) -> ModeRun {
    let mut phases: Vec<(String, f64)> = Vec::new();
    let t_total = Instant::now();

    let t = Instant::now();
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(cfg, cfg.cluster.router);
    cluster.use_indexes = use_indexes;
    phases.push(("build".to_string(), t.elapsed().as_secs_f64()));

    let t = Instant::now();
    cluster.prewarm();
    phases.push(("prewarm".to_string(), t.elapsed().as_secs_f64()));

    let t = Instant::now();
    cluster.run(workload.requests).expect("cluster run failed");
    let run_s = t.elapsed().as_secs_f64();
    phases.push(("run".to_string(), run_s));

    let kernel_events = cluster.kernel_events;
    let replica_steps = cluster.replica_steps;

    let t = Instant::now();
    let report = cluster.report(cfg.warmup_fraction);
    let hit_rate = report.fastpath.hit_rate();
    let report_full = deterministic_json(report.clone(), false);
    let report_stripped = deterministic_json(report, true);
    phases.push(("report".to_string(), t.elapsed().as_secs_f64()));

    let stats = PerfStats {
        wall_s: t_total.elapsed().as_secs_f64(),
        kernel_events,
        replica_steps,
        events_per_sec: (kernel_events + replica_steps) as f64
            / run_s.max(1e-9),
        peak_rss_mb: peak_rss_mb(),
        phases,
    };
    ModeRun { stats, report_full, report_stripped, hit_rate }
}

/// Run one scenario through both gates; returns `(indexed, oracle)`.
fn run_scenario(
    label: &str,
    cfg: &ExperimentConfig,
    skip_oracle: bool,
) -> (ModeRun, Option<ModeRun>) {
    println!(
        "== {label} — {} replicas, {} requests, router {} ==",
        cfg.cluster.replicas,
        cfg.workload.n_requests,
        cfg.cluster.router.name()
    );
    // gate 1: run-twice determinism of the indexed path (counters included)
    let indexed = run_mode(cfg, true);
    print_stats("indexed", &indexed.stats);
    println!("  fast-path hit rate: {:.3}", indexed.hit_rate);
    let again = run_mode(cfg, true);
    if indexed.report_full != again.report_full {
        eprintln!("FAIL: {label}: two indexed runs produced different reports");
        std::process::exit(1);
    }
    println!("  run-twice: reports byte-identical");

    // gate 2: indexed vs full-rescan oracle (fast-path counters stripped —
    // the one section designed to differ between modes)
    let oracle = if skip_oracle {
        println!("  oracle: skipped (--skip-oracle)");
        None
    } else {
        let o = run_mode(cfg, false);
        print_stats("oracle", &o.stats);
        if o.report_stripped != indexed.report_stripped {
            eprintln!(
                "FAIL: {label}: indexed report diverged from the rescan oracle"
            );
            std::process::exit(1);
        }
        println!("  oracle: reports byte-identical");
        Some(o)
    };
    let speedup = oracle.as_ref().map(|o| {
        indexed.stats.events_per_sec / o.stats.events_per_sec.max(1e-9)
    });
    if let Some(s) = speedup {
        println!("  speedup: {s:.1}x events/sec");
    }
    (indexed, oracle)
}

/// The per-scenario block of the output JSON.
fn scenario_json(
    cfg: &ExperimentConfig,
    indexed: &ModeRun,
    oracle: &Option<ModeRun>,
) -> Vec<(&'static str, Json)> {
    let speedup = oracle.as_ref().map(|o| {
        indexed.stats.events_per_sec / o.stats.events_per_sec.max(1e-9)
    });
    vec![
        ("replicas", Json::num(cfg.cluster.replicas as f64)),
        ("requests", Json::num(cfg.workload.n_requests as f64)),
        ("router", Json::str(cfg.cluster.router.name())),
        ("indexed", indexed.stats.to_json()),
        ("fastpath_hit_rate", Json::num(indexed.hit_rate)),
        (
            "oracle",
            oracle
                .as_ref()
                .map(|o| o.stats.to_json())
                .unwrap_or(Json::Null),
        ),
        (
            "speedup_events_per_sec",
            speedup.map(Json::num).unwrap_or(Json::Null),
        ),
        ("reports_byte_identical", Json::Bool(true)),
    ]
}

fn print_stats(label: &str, s: &PerfStats) {
    println!(
        "  {label:>8}: {:.2}s wall, {} events + {} steps, {:.0} events/s, \
         peak RSS {:.0} MB",
        s.wall_s, s.kernel_events, s.replica_steps, s.events_per_sec,
        s.peak_rss_mb
    );
    for (name, secs) in &s.phases {
        println!("           - {name}: {secs:.3}s");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let skip_oracle = args.iter().any(|a| a == "--skip-oracle");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or(if smoke {
            "bench_out/BENCH_cluster.json"
        } else {
            "BENCH_cluster.json"
        })
        .to_string();

    let cfg = scenario(smoke);
    let label = format!(
        "cluster_scale ({}) baseline",
        if smoke { "smoke" } else { "full" }
    );
    let (indexed, oracle) = run_scenario(&label, &cfg, skip_oracle);

    // gate 3 (smoke / CI): the baseline's combined hit rate must hold its
    // floor, so a change that silently diverts dispatch onto the rescan
    // path fails even though the reports still agree
    if smoke && indexed.hit_rate < SMOKE_HIT_RATE_FLOOR {
        eprintln!(
            "FAIL: smoke fast-path hit rate {:.3} below the {SMOKE_HIT_RATE_FLOOR} floor",
            indexed.hit_rate
        );
        std::process::exit(1);
    }
    if smoke {
        println!(
            "  hit-rate floor: {:.3} >= {SMOKE_HIT_RATE_FLOOR}",
            indexed.hit_rate
        );
    }

    let sd_cfg = scenario_session_disagg(smoke);
    let sd_label = format!(
        "cluster_scale ({}) session+disagg",
        if smoke { "smoke" } else { "full" }
    );
    let (sd_indexed, sd_oracle) = run_scenario(&sd_label, &sd_cfg, skip_oracle);

    let mut fields = vec![
        ("bench", Json::str("cluster_scale")),
        ("mode", Json::str(if smoke { "smoke" } else { "full" })),
    ];
    fields.extend(scenario_json(&cfg, &indexed, &oracle));
    fields.push((
        "session_disagg",
        Json::obj(scenario_json(&sd_cfg, &sd_indexed, &sd_oracle)),
    ));
    let json = Json::obj(fields);
    if let Some(dir) = std::path::Path::new(&out).parent() {
        if !dir.as_os_str().is_empty() {
            let _ = std::fs::create_dir_all(dir);
        }
    }
    match std::fs::write(&out, format!("{json}\n")) {
        Ok(()) => println!("  [json] {out}"),
        Err(e) => {
            eprintln!("FAIL: could not write {out}: {e}");
            std::process::exit(1);
        }
    }
}
