//! A/B: host-copy decode path vs literal-chaining decode path (§Perf).
use std::time::Instant;
fn main() {
    let rt = sagesched::runtime::Runtime::load("artifacts").unwrap();
    let m = rt.meta().clone();
    let ce = m.cache_elems();
    let toks = vec![m.pad_id as i32; m.decode_batch];
    let pos = vec![1i32; m.decode_batch];
    // warmup
    let mut k = vec![0.01f32; ce];
    let mut v = vec![0.01f32; ce];
    for _ in 0..5 { let o = rt.run_decode(&toks, &pos, &k, &v).unwrap(); k = o.k; v = o.v; }
    let t0 = Instant::now();
    for _ in 0..100 { let o = rt.run_decode(&toks, &pos, &k, &v).unwrap(); k = o.k; v = o.v; }
    println!("host-copy path   : {:.2} ms/step", t0.elapsed().as_secs_f64() * 10.0);
    let mut kl = rt.cache_literal(&k).unwrap();
    let mut vl = rt.cache_literal(&v).unwrap();
    for _ in 0..5 { let o = rt.run_decode_lit(&toks, &pos, &kl, &vl).unwrap(); kl = o.k; vl = o.v; }
    let t0 = Instant::now();
    for _ in 0..100 { let o = rt.run_decode_lit(&toks, &pos, &kl, &vl).unwrap(); kl = o.k; vl = o.v; }
    println!("literal-chaining : {:.2} ms/step", t0.elapsed().as_secs_f64() * 10.0);
}
