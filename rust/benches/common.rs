//! Shared helpers for the hand-rolled bench harnesses (criterion is not
//! available offline): wallclock timing with warmup, and CSV emission.
//! Each bench target uses a subset of these, hence the allow(dead_code).
#![allow(dead_code)]

use std::io::Write;
use std::time::Instant;

/// Time `f` with `warmup` + `iters` iterations; returns ns/op (median of 5
/// batches).
pub fn time_ns(mut f: impl FnMut(), warmup: usize, iters: usize) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut batches = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        batches.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    batches.sort_by(|a, b| a.partial_cmp(b).unwrap());
    batches[2]
}

/// Pretty-print ns as an adaptive unit string.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Write rows to `bench_out/<name>.csv` (header first).
pub fn write_csv(name: &str, header: &str, rows: &[String]) {
    let dir = std::path::Path::new("bench_out");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.csv"));
    match std::fs::File::create(&path) {
        Ok(mut f) => {
            let _ = writeln!(f, "{header}");
            for r in rows {
                let _ = writeln!(f, "{r}");
            }
            println!("  [csv] {}", path.display());
        }
        Err(e) => eprintln!("  [csv] failed to write {}: {e}", path.display()),
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Map `f` over `items` on a bounded pool of std threads (rayon is
/// unavailable offline), returning results in input order. The items form
/// one shared work queue drained by `min(len, available_parallelism)`
/// workers, so a flattened router × seed grid keeps every core busy until
/// the queue is empty instead of over-subscribing one thread per item.
/// Each run is internally deterministic and results are re-assembled in
/// input order, so same-seed outputs (and printed order) are unchanged:
/// only wall-clock drops.
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    let n = items.len();
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(n)
        .max(1);
    let f = &f;
    // hand-rolled claim-by-index queue: workers bump `next` and write the
    // result into the slot of the item they claimed, preserving input order
    let queue: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|it| Mutex::new(Some(it))).collect();
    let queue = &queue;
    let next = AtomicUsize::new(0);
    let next = &next;
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let slots = &slots;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    let it = queue[i]
                        .lock()
                        .expect("parallel_map queue poisoned")
                        .take()
                        .expect("parallel_map item claimed twice");
                    let r = f(it);
                    *slots[i].lock().expect("parallel_map slot poisoned") = Some(r);
                })
            })
            .collect();
        for h in handles {
            h.join().expect("parallel_map worker panicked");
        }
    });
    slots
        .iter()
        .map(|slot| {
            slot.lock()
                .expect("parallel_map slot poisoned")
                .take()
                .expect("parallel_map worker left a slot empty")
        })
        .collect()
}
