//! Shared helpers for the hand-rolled bench harnesses (criterion is not
//! available offline): wallclock timing with warmup, and CSV emission.
//! Each bench target uses a subset of these, hence the allow(dead_code).
#![allow(dead_code)]

use std::io::Write;
use std::time::Instant;

/// Time `f` with `warmup` + `iters` iterations; returns ns/op (median of 5
/// batches).
pub fn time_ns(mut f: impl FnMut(), warmup: usize, iters: usize) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut batches = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        batches.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    batches.sort_by(|a, b| a.partial_cmp(b).unwrap());
    batches[2]
}

/// Pretty-print ns as an adaptive unit string.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Write rows to `bench_out/<name>.csv` (header first).
pub fn write_csv(name: &str, header: &str, rows: &[String]) {
    let dir = std::path::Path::new("bench_out");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.csv"));
    match std::fs::File::create(&path) {
        Ok(mut f) => {
            let _ = writeln!(f, "{header}");
            for r in rows {
                let _ = writeln!(f, "{r}");
            }
            println!("  [csv] {}", path.display());
        }
        Err(e) => eprintln!("  [csv] failed to write {}: {e}", path.display()),
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Map `f` over `items` on one std thread each (rayon is unavailable
/// offline), returning results in input order. Intended for a handful of
/// independent sims — the fig sweeps run the same seeded workload under
/// several routers/policies, and each run is internally deterministic, so
/// same-seed outputs are unchanged: only wall-clock drops.
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .into_iter()
            .map(|it| s.spawn(move || f(it)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel_map worker panicked"))
            .collect()
    })
}
