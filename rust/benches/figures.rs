//! Regenerates every table and figure of the SageSched paper's evaluation
//! (§2 motivation + §4 evaluation). One sub-command per figure:
//!
//! ```text
//! cargo bench --bench figures            # everything
//! cargo bench --bench figures -- fig7    # one figure
//! cargo bench --bench figures -- fig7 --quick   # reduced sizes (CI)
//! ```
//!
//! Each figure prints the paper-style rows/series and writes a CSV under
//! `bench_out/`. Absolute numbers come from the calibrated simulator (see
//! DESIGN.md §Substitutions); the claims under reproduction are the
//! *shapes*: who wins, by roughly what factor, where crossovers fall.

mod common;

use common::{mean, parallel_map, write_csv};

use sagesched::cluster::ClusterSim;
use sagesched::config::{
    CostModelKind, DatasetKind, EngineProfile, ExperimentConfig, PolicyKind,
    PredictorKind, WorkloadConfig,
};
use sagesched::cost::{CostModel, OutputLenCost, ResourceBoundCost};
use sagesched::distribution::LengthDist;
use sagesched::engine::{Engine, LaneState, SimEngine};
use sagesched::gittins::gittins_index;
use sagesched::predictor::ProxyPredictor;
use sagesched::serve::{prewarm_predictor, run_experiment};
use sagesched::util::rng::Rng;
use sagesched::workload::WorkloadGen;

struct Ctx {
    quick: bool,
}

impl Ctx {
    fn n_requests(&self, full: usize) -> usize {
        if self.quick { full / 4 } else { full }
    }

    fn seeds(&self, full: u64) -> Vec<u64> {
        (0..if self.quick { 1 } else { full }).collect()
    }
}

/// Run one experiment and return (mean TTLT, mean TTFT).
fn run_point(cfg: &ExperimentConfig) -> (f64, f64) {
    let r = run_experiment(cfg).expect("experiment failed");
    (r.ttlt.mean, r.ttft.mean)
}

/// Column means of one parameter point's per-seed `(ttlt, ttft)` chunk.
fn point_means(chunk: &[(f64, f64)]) -> (f64, f64) {
    let ttlts: Vec<f64> = chunk.iter().map(|p| p.0).collect();
    let ttfts: Vec<f64> = chunk.iter().map(|p| p.1).collect();
    (mean(&ttlts), mean(&ttfts))
}

/// Default predictor pairing per policy, as each baseline's paper uses.
fn natural_predictor(policy: PolicyKind) -> PredictorKind {
    match policy {
        PolicyKind::Ssjf => PredictorKind::Proxy,
        _ => PredictorKind::History,
    }
}

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig::default()
}

// ===========================================================================
// Fig 1(a): output-length variation of fixed prompts over repeated runs
// ===========================================================================
fn fig1a(ctx: &Ctx) {
    println!("\n=== fig1a: output-length variation (10 prompts x 100 trials) ===");
    let wl = WorkloadConfig::default();
    let mut gen = WorkloadGen::new(wl, 7);
    let trials = ctx.n_requests(100);
    let mut rows = Vec::new();
    println!("| prompt | dataset | min | p25 | median | p75 | max |");
    println!("|---|---|---|---|---|---|---|");
    let n_topics = gen.topics().len();
    let mut rng = Rng::new(99);
    for p in 0..10 {
        let topic_idx = (rng.below(n_topics as u64)) as usize;
        let mut lens: Vec<f64> = (0..trials)
            .map(|i| gen.sample_from_topic(topic_idx, i as f64).true_output_len as f64)
            .collect();
        lens.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |f: f64| lens[((lens.len() - 1) as f64 * f) as usize];
        let ds = gen.topics()[topic_idx].dataset.name();
        println!(
            "| {p} | {ds} | {:.0} | {:.0} | {:.0} | {:.0} | {:.0} |",
            lens[0],
            q(0.25),
            q(0.5),
            q(0.75),
            lens[lens.len() - 1]
        );
        rows.push(format!(
            "{p},{ds},{},{},{},{},{}",
            lens[0],
            q(0.25),
            q(0.5),
            q(0.75),
            lens[lens.len() - 1]
        ));
    }
    write_csv("fig1a", "prompt,dataset,min,p25,median,p75,max", &rows);
    println!("  (same prompt, wide spread: demand uncertainty is intrinsic)");
}

// ===========================================================================
// Fig 1(b): (execution time, peak memory) scatter per dataset
// ===========================================================================
fn fig1b(ctx: &Ctx) {
    println!("\n=== fig1b: per-request (exec time, peak KV) by dataset ===");
    let n = ctx.n_requests(200);
    let mut rows = Vec::new();
    println!("| dataset | mean exec (s) | mean peak KV (tokens) | corr(exec, mem) |");
    println!("|---|---|---|---|");
    for ds in DatasetKind::ALL {
        let mut wl = WorkloadConfig::single(ds);
        wl.n_requests = n;
        let workload = WorkloadGen::new(wl, 11).generate();
        let engine = SimEngine::new(EngineProfile::h800_qwen32b());
        let mut execs = Vec::new();
        let mut mems = Vec::new();
        for r in &workload.requests {
            // request profiled ALONE (as the paper does)
            let i = r.input_len as f64;
            let o = r.true_output_len as f64;
            let mut t = engine.prefill_time(r.input_len);
            for g in 1..r.true_output_len {
                let (step, _, _) = engine.step_terms(1, (r.input_len + g) as usize);
                t += step;
            }
            let peak = i + o;
            execs.push(t);
            mems.push(peak);
            rows.push(format!("{},{t:.3},{peak}", ds.name()));
        }
        let (me, mm) = (mean(&execs), mean(&mems));
        let cov: f64 = execs.iter().zip(&mems).map(|(a, b)| (a - me) * (b - mm)).sum();
        let va: f64 = execs.iter().map(|a| (a - me) * (a - me)).sum();
        let vb: f64 = mems.iter().map(|b| (b - mm) * (b - mm)).sum();
        let corr = cov / (va.sqrt() * vb.sqrt()).max(1e-12);
        println!("| {} | {:.2} | {:.0} | {:.2} |", ds.name(), me, mm, corr);
    }
    write_csv("fig1b", "dataset,exec_s,peak_kv_tokens", &rows);
    println!("  (alpaca: high mem, low exec; write: high exec — hybridity)");
}

// ===========================================================================
// Fig 2(a): single-value predictor bucket accuracy
// ===========================================================================
fn fig2a(ctx: &Ctx) {
    println!("\n=== fig2a: point-prediction bucket accuracy (100-token buckets) ===");
    let n = ctx.n_requests(2000);
    let mut wl = WorkloadConfig::default();
    wl.n_requests = n;
    let workload = WorkloadGen::new(wl, 13).generate();
    let mut proxy = ProxyPredictor::new(13);
    let mut hits = 0usize;
    let mut dist_hits = 0usize;
    for r in &workload.requests {
        let expected = r.true_dist.as_ref().unwrap().mean();
        let point = proxy.noisy_point(expected.round() as u32);
        let truth_bucket = (r.true_output_len / 100) as i64;
        if (point / 100.0).floor() as i64 == truth_bucket {
            hits += 1;
        }
        // the distribution prediction "covers" the truth if it puts >=5%
        // mass on the true bucket
        let d = r.true_dist.as_ref().unwrap();
        let lo = (truth_bucket * 100) as f64;
        let mass = d.cdf(lo + 100.0) - d.cdf(lo);
        if mass >= 0.05 {
            dist_hits += 1;
        }
    }
    let acc = hits as f64 / n as f64;
    let dacc = dist_hits as f64 / n as f64;
    println!("| predictor | bucket accuracy |");
    println!("|---|---|");
    println!("| single-value (DistillBert-style proxy) | {:.1}% |", acc * 100.0);
    println!("| distribution (>=5% mass on true bucket) | {:.1}% |", dacc * 100.0);
    write_csv(
        "fig2a",
        "predictor,accuracy",
        &[format!("point,{acc:.4}"), format!("distribution,{dacc:.4}")],
    );
    println!("  (paper: 34.1% for the single-value predictor)");
}

// ===========================================================================
// Fig 2(b): shortest-output-first is suboptimal under memory pressure
// ===========================================================================
fn fig2b(_ctx: &Ctx) {
    println!("\n=== fig2b: memory-bound counter-example (2 orders) ===");
    // Request A: short output, huge input (heavy KV). B: longer output,
    // tiny input. Under a memory-tight backend, output-length order runs A
    // first; the resource-bound cost picks B first and wins on avg TTLT.
    let mk = |id, input, output| sagesched::core::Request {
        id,
        prompt: String::new(),
        input_len: input,
        true_output_len: output,
        arrival: 0.0,
        dataset: DatasetKind::Alpaca,
        topic: 0,
        embedding: sagesched::embedding::Embedding::normalize(vec![1.0, 0.0]),
        true_dist: Some(LengthDist::point(output as f64)),
        slo: sagesched::slo::SloClass::Standard,
        prefix_key: Vec::new(),
    };
    // A: shortest output but a giant prompt — it monopolizes the KV pool.
    // Seven chat requests (slightly longer outputs, tiny prompts) could run
    // *concurrently* if A deferred.
    let a = mk(1, 1800, 55);
    let smalls: Vec<_> = (2..=8).map(|i| mk(i, 40, 60 + 5 * (i as u32 % 3))).collect();

    let rb = ResourceBoundCost;
    let ol = OutputLenCost;
    println!("| request | I | O | C=O (output-len) | C=O²/2+IO (resource-bound) |");
    println!("|---|---|---|---|---|");
    for r in std::iter::once(&a).chain(smalls.iter().take(2)) {
        println!(
            "| {} | {} | {} | {:.0} | {:.0} |",
            r.id,
            r.input_len,
            r.true_output_len,
            ol.cost(r.input_len, r.true_output_len as f64),
            rb.cost(r.input_len, r.true_output_len as f64)
        );
    }
    let mut profile = EngineProfile::h800_qwen32b();
    profile.kv_capacity = 2_000; // A cannot co-reside with the chat batch
    let serve_with = |policy: PolicyKind| {
        let mut cfg = base_cfg();
        cfg.engine = profile.clone();
        cfg.policy = policy;
        cfg.predictor = PredictorKind::Oracle;
        let mut coord = sagesched::serve::build_sim_coordinator(&cfg);
        coord
            .run_workload(
                std::iter::once(a.clone()).chain(smalls.iter().cloned()).collect(),
            )
            .unwrap();
        mean(&coord.outcomes().iter().map(|o| o.ttlt()).collect::<Vec<_>>())
    };
    // SSJF with an oracle point prediction == exact shortest-output-first
    let short_first = serve_with(PolicyKind::Ssjf);
    // oracle SRPT under the resource-bound cost defers the memory hog
    let cheap_first = serve_with(PolicyKind::OracleSrpt);
    println!("\n| order | avg TTLT (s) |");
    println!("|---|---|");
    println!("| shorter-output first (A, then chats) | {short_first:.3} |");
    println!("| resource-bound first (chats co-run, A last) | {cheap_first:.3} |");
    write_csv(
        "fig2b",
        "order,avg_ttlt",
        &[
            format!("shorter_output_first,{short_first:.4}"),
            format!("resource_bound_first,{cheap_first:.4}"),
        ],
    );
    assert!(cheap_first < short_first, "counter-example must hold");
    println!("  (prioritizing by output length alone is suboptimal — hybridity)");
}

// ===========================================================================
// Fig 4: prompt similarity <-> output-length-distribution similarity
// ===========================================================================
fn fig4(ctx: &Ctx) {
    println!("\n=== fig4: similarity bands vs distribution distance ===");
    let trials = ctx.n_requests(100);
    let mut rows = Vec::new();
    println!("| prompt | band | records | W1 to target dist |");
    println!("|---|---|---|---|");
    for (label, ds, topic_off) in [
        ("prompt-1-alpaca", DatasetKind::Alpaca, 0usize),
        ("prompt-2-write", DatasetKind::Write, 2),
    ] {
        let mut wl = WorkloadConfig::default();
        wl.n_requests = 0;
        let mut gen = WorkloadGen::new(wl, 17);
        let topic_idx =
            gen.topics().iter().position(|t| t.dataset == ds).unwrap() + topic_off;
        let target_lens: Vec<f64> = (0..trials)
            .map(|i| gen.sample_from_topic(topic_idx, i as f64).true_output_len as f64)
            .collect();
        let target = LengthDist::from_samples(&target_lens);
        let probe = gen.sample_from_topic(topic_idx, 0.0);

        let mut wl2 = WorkloadConfig::default();
        wl2.n_requests = ctx.n_requests(4000);
        let hist = WorkloadGen::new(wl2, 19).generate();
        let mut bands: [(f32, f32, Vec<f64>); 3] = [
            (0.8, 1.01, Vec::new()),
            (0.4, 0.8, Vec::new()),
            (-1.0, 0.4, Vec::new()),
        ];
        for r in &hist.requests {
            let s = probe.embedding.cosine(&r.embedding);
            for (lo, hi, v) in bands.iter_mut() {
                if s >= *lo && s < *hi {
                    v.push(r.true_output_len as f64);
                }
            }
        }
        for (lo, hi, lens) in &bands {
            if lens.len() < 3 {
                continue;
            }
            let d = LengthDist::from_samples(lens);
            let w1 = d.w1_distance(&target);
            println!("| {label} | [{lo:.1},{hi:.1}) | {} | {w1:.1} |", lens.len());
            rows.push(format!("{label},{lo},{hi},{},{w1:.2}", lens.len()));
        }
    }
    write_csv("fig4", "prompt,band_lo,band_hi,records,w1", &rows);
    println!("  (higher similarity band -> closer to the target distribution)");
}

// ===========================================================================
// Fig 5(a): GPU utilization vs KV occupation as batch grows
// ===========================================================================
fn fig5a(_ctx: &Ctx) {
    println!("\n=== fig5a: util vs KV occupation, seq 50 vs 1000 ===");
    let engine = SimEngine::new(EngineProfile::h800_qwen32b());
    let cap = engine.profile().kv_capacity as f64;
    let mut rows = Vec::new();
    println!("| seq len | batch | GPU util | KV occupation |");
    println!("|---|---|---|---|");
    // "GPU util" = achieved/peak FLOPs: the per-sequence GEMM work (c1·B)
    // amortizes the weight-streaming constant (c0), so utilization ramps
    // with batch size — until the KV pool is full and the batch can't grow.
    let c1 = engine.profile().decode_c1;
    for seq in [50usize, 1000] {
        for batch in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
            let resident = batch * seq;
            if resident as f64 > cap {
                break;
            }
            let (step, _, _) = engine.step_terms(batch, resident);
            let util = (c1 * 2.0 * batch as f64 / step).min(1.0);
            let occ = resident as f64 / cap;
            println!("| {seq} | {batch} | {util:.2} | {occ:.2} |");
            rows.push(format!("{seq},{batch},{util:.4},{occ:.4}"));
        }
    }
    write_csv("fig5a", "seq,batch,util,kv_occupation", &rows);
    println!("  (short seqs: util saturates before memory; long seqs: memory fills first)");
}

// ===========================================================================
// Fig 5(b): per-step attention time vs decode progress
// ===========================================================================
fn fig5b(ctx: &Ctx) {
    println!("\n=== fig5b: per-step time vs decode step (seq grows) ===");
    let engine = SimEngine::new(EngineProfile::h800_qwen32b());
    let mut rows = Vec::new();
    println!("| decode step | sim step time (ms) |");
    println!("|---|---|");
    for step_idx in (0..=4000usize).step_by(500) {
        let resident = 128 + step_idx;
        let (t, _, _) = engine.step_terms(1, resident);
        println!("| {step_idx} | {:.3} |", t * 1e3);
        rows.push(format!("{step_idx},{:.6}", t * 1e3));
    }
    write_csv("fig5b", "decode_step,step_ms", &rows);

    // real-engine measurement when artifacts exist: per-step wallclock of
    // the compiled decode HLO (pallas flash-decode inside)
    if sagesched::runtime::Runtime::artifacts_present("artifacts") && !ctx.quick {
        use sagesched::engine::RealEngine;
        let rt = sagesched::runtime::Runtime::load("artifacts").unwrap();
        let mut eng = RealEngine::new(rt, 1);
        let req = sagesched::core::Request {
            id: 1,
            prompt: "measure decode step scaling with sequence length".into(),
            input_len: 10,
            true_output_len: u32::MAX,
            arrival: 0.0,
            dataset: DatasetKind::Write,
            topic: 0,
            embedding: sagesched::embedding::Embedding::normalize(vec![1.0; 4]),
            true_dist: None,
            slo: sagesched::slo::SloClass::Standard,
            prefix_key: Vec::new(),
        };
        eng.max_output = 240;
        let _ = eng.prefill(&req).unwrap();
        let mut lanes = vec![LaneState::new(&req, 1)];
        let mut real_rows = Vec::new();
        let mut step = 0;
        println!("\n| decode step (real HLO) | ms |");
        println!("|---|---|");
        while step < 200 {
            let dt = eng.decode_step(&mut lanes, 0).unwrap();
            if step % 25 == 0 {
                println!("| {step} | {:.2} |", dt * 1e3);
            }
            real_rows.push(format!("{step},{:.4}", dt * 1e3));
            lanes[0].finished = false; // keep generating for measurement
            step += 1;
        }
        write_csv("fig5b_real", "decode_step,step_ms", &real_rows);
    }
}

// ===========================================================================
// Fig 6: Mean vs Gittins on the bimodal example
// ===========================================================================
fn fig6(_ctx: &Ctx) {
    println!("\n=== fig6: mean-value vs Gittins prioritization ===");
    let a = LengthDist::from_weighted(&[(80.0, 0.5), (120.0, 0.5)]);
    let b = LengthDist::from_weighted(&[(10.0, 0.6), (400.0, 0.4)]);
    println!("| request | mean cost | Gittins index |");
    println!("|---|---|---|");
    println!("| A (concentrated) | {:.0} | {:.1} |", a.mean(), gittins_index(&a));
    println!("| B (bimodal) | {:.0} | {:.1} |", b.mean(), gittins_index(&b));
    // Monte-Carlo expected average completion under three disciplines
    let mut rng = Rng::new(5);
    let trials = 20_000;
    let (mut ab, mut ba, mut gittins_refresh) = (0.0, 0.0, 0.0);
    for _ in 0..trials {
        let xa = a.sample(&mut rng);
        let xb = b.sample(&mut rng);
        // A first (Mean's choice): T_A = xa, T_B = xa + xb
        ab += (xa + (xa + xb)) / 2.0;
        // B first: T_B = xb, T_A = xb + xa
        ba += (xb + (xb + xa)) / 2.0;
        // Gittins + refresh: serve B up to its short mode (10); if it
        // missed, park B, serve A, then finish B
        if xb <= 10.0 {
            gittins_refresh += (xb + (xb + xa)) / 2.0;
        } else {
            let t_a = 10.0 + xa;
            let t_b = t_a + (xb - 10.0);
            gittins_refresh += (t_a + t_b) / 2.0;
        }
    }
    let (ab, ba, gr) = (ab / trials as f64, ba / trials as f64, gittins_refresh / trials as f64);
    println!("\n| discipline | expected avg completion |");
    println!("|---|---|");
    println!("| A first (Mean's choice) | {ab:.0} |");
    println!("| B first (Gittins' choice) | {ba:.0} |");
    println!("| Gittins + bucket refresh | {gr:.0} |");
    write_csv(
        "fig6",
        "discipline,avg_completion",
        &[
            format!("mean_first_A,{ab:.2}"),
            format!("gittins_first_B,{ba:.2}"),
            format!("gittins_refresh,{gr:.2}"),
        ],
    );
    assert!(gr < ab, "refreshing Gittins must beat mean ordering");
}

// ===========================================================================
// Fig 7: end-to-end mixed-dataset comparison (the headline figure)
// ===========================================================================
fn fig7(ctx: &Ctx) {
    println!("\n=== fig7: end-to-end TTLT/TTFT, mixed datasets ===");
    let engines = [EngineProfile::a40_llama8b(), EngineProfile::h800_qwen32b()];
    let rates = [4.0, 6.0, 8.0, 10.0, 12.0];
    let seeds = ctx.seeds(2);
    // flatten the whole engine x rps x policy x seed grid into one work
    // queue so the pool stays busy across cells; printing below walks the
    // results in the same order the grid was built, so output is unchanged
    let mut cfgs = Vec::new();
    for engine in &engines {
        for &rps in &rates {
            for policy in PolicyKind::PAPER_BASELINES {
                for &seed in &seeds {
                    let mut cfg = base_cfg();
                    cfg.engine = engine.clone();
                    cfg.policy = policy;
                    cfg.predictor = natural_predictor(policy);
                    cfg.workload.rps = rps;
                    cfg.workload.n_requests = ctx.n_requests(1200);
                    cfg.seed = seed;
                    cfgs.push(cfg);
                }
            }
        }
    }
    let points = parallel_map(cfgs, |cfg| run_point(&cfg));
    let mut chunks = points.chunks(seeds.len());
    let mut rows = Vec::new();
    for engine in &engines {
        for rps in rates {
            println!("\n-- {} @ {rps} rps --", engine.name);
            println!("| policy | TTLT mean | TTFT mean |");
            println!("|---|---|---|");
            let mut best_baseline = f64::INFINITY;
            let mut sage = f64::INFINITY;
            for policy in PolicyKind::PAPER_BASELINES {
                let chunk = chunks.next().expect("fig7 grid/result mismatch");
                let (t, f) = point_means(chunk);
                println!("| {} | {t:.2} | {f:.2} |", policy.name());
                rows.push(format!(
                    "{},{rps},{},{t:.3},{f:.3}",
                    engine.name,
                    policy.name()
                ));
                if policy == PolicyKind::SageSched {
                    sage = t;
                } else if t < best_baseline {
                    best_baseline = t;
                }
            }
            let gain = (best_baseline - sage) / best_baseline * 100.0;
            println!("  -> sagesched vs best baseline: {gain:+.1}%");
        }
    }
    write_csv("fig7", "engine,rps,policy,ttlt_mean,ttft_mean", &rows);
}

// ===========================================================================
// Fig 8: per-dataset end-to-end
// ===========================================================================
fn fig8(ctx: &Ctx) {
    println!("\n=== fig8: end-to-end per dataset (h800 @ 8 rps) ===");
    let seeds = ctx.seeds(2);
    // one flat dataset x policy x seed queue (see fig7)
    let mut cfgs = Vec::new();
    for ds in DatasetKind::ALL {
        for policy in PolicyKind::PAPER_BASELINES {
            for &seed in &seeds {
                let mut cfg = base_cfg();
                cfg.engine = EngineProfile::h800_qwen32b();
                cfg.policy = policy;
                cfg.predictor = natural_predictor(policy);
                cfg.workload = WorkloadConfig::single(ds);
                cfg.workload.rps = 8.0;
                cfg.workload.n_requests = ctx.n_requests(1200);
                cfg.seed = seed;
                cfgs.push(cfg);
            }
        }
    }
    let points = parallel_map(cfgs, |cfg| run_point(&cfg));
    let mut chunks = points.chunks(seeds.len());
    let mut rows = Vec::new();
    for ds in DatasetKind::ALL {
        println!("\n-- {} --", ds.name());
        println!("| policy | TTLT mean | TTFT mean |");
        println!("|---|---|---|");
        for policy in PolicyKind::PAPER_BASELINES {
            let chunk = chunks.next().expect("fig8 grid/result mismatch");
            let (t, f) = point_means(chunk);
            println!("| {} | {t:.2} | {f:.2} |", policy.name());
            rows.push(format!(
                "{},{},{t:.3},{f:.3}",
                ds.name(),
                policy.name()
            ));
        }
    }
    write_csv("fig8", "dataset,policy,ttlt_mean,ttft_mean", &rows);
}

// ===========================================================================
// Fig 9: predictor ablation
// ===========================================================================
fn fig9(ctx: &Ctx) {
    println!("\n=== fig9: predictor ablation (SageSched policy) ===");
    println!("| predictor | TTLT mean | W1(pred, true) |");
    println!("|---|---|---|");
    let preds = [
        PredictorKind::History,
        PredictorKind::LengthHistory,
        PredictorKind::Proxy,
        PredictorKind::Oracle,
    ];
    let seeds = ctx.seeds(2);
    // one flat predictor x seed queue; the cheap W1 probe stays in the
    // sequential print loop
    let mut cfgs = Vec::new();
    for &pred in &preds {
        for &seed in &seeds {
            let mut cfg = base_cfg();
            cfg.policy = PolicyKind::SageSched;
            cfg.predictor = pred;
            cfg.workload.rps = 8.0;
            cfg.workload.n_requests = ctx.n_requests(1200);
            cfg.seed = seed;
            cfgs.push(cfg);
        }
    }
    let points = parallel_map(cfgs, |cfg| run_point(&cfg));
    let mut chunks = points.chunks(seeds.len());
    let mut rows = Vec::new();
    for pred in preds {
        let chunk = chunks.next().expect("fig9 grid/result mismatch");
        let (ttlt, _) = point_means(chunk);
        // prediction quality probe
        let cfg = base_cfg();
        let mut p = sagesched::predictor::make_predictor(pred, 64, 10_000, 0.8, 3);
        prewarm_predictor(p.as_mut(), &cfg);
        let mut wl = cfg.workload.clone();
        wl.n_requests = 300;
        let probes = WorkloadGen::new(wl, 23).generate();
        let w1: f64 = probes
            .requests
            .iter()
            .map(|r| p.predict(r).w1_distance(r.true_dist.as_ref().unwrap()))
            .sum::<f64>()
            / probes.requests.len() as f64;
        println!("| {} | {ttlt:.2} | {w1:.1} |", pred.name());
        rows.push(format!("{},{ttlt:.3},{w1:.2}", pred.name()));
    }
    write_csv("fig9", "predictor,ttlt_mean,w1", &rows);
}

// ===========================================================================
// Fig 10: cost-model ablation
// ===========================================================================
fn fig10(ctx: &Ctx) {
    println!("\n=== fig10: cost-model ablation (SageSched policy) ===");
    println!("| cost model | TTLT mean |");
    println!("|---|---|");
    let cms = [
        CostModelKind::ResourceBound,
        CostModelKind::OutputLen,
        CostModelKind::OverallLen,
    ];
    let seeds = ctx.seeds(3);
    // one flat cost-model x seed queue (see fig7)
    let mut cfgs = Vec::new();
    for &cm in &cms {
        for &seed in &seeds {
            let mut cfg = base_cfg();
            cfg.policy = PolicyKind::SageSched;
            cfg.cost_model = cm;
            cfg.workload.rps = 8.0;
            cfg.workload.n_requests = ctx.n_requests(1200);
            cfg.seed = seed;
            cfgs.push(cfg);
        }
    }
    let points = parallel_map(cfgs, |cfg| run_point(&cfg));
    let mut chunks = points.chunks(seeds.len());
    let mut rows = Vec::new();
    for cm in cms {
        let chunk = chunks.next().expect("fig10 grid/result mismatch");
        let (ttlt, _) = point_means(chunk);
        println!("| {} | {ttlt:.2} |", cm.name());
        rows.push(format!("{},{ttlt:.3}", cm.name()));
    }
    write_csv("fig10", "cost_model,ttlt_mean", &rows);
}

// ===========================================================================
// Fig 11: scheduling ablation + noise robustness
// ===========================================================================
fn fig11(ctx: &Ctx) {
    println!("\n=== fig11: Mean vs Gittins vs SageSched, +noise ===");
    println!("| policy | TTLT (clean) | TTLT (noisy 1:4) | degradation |");
    println!("|---|---|---|---|");
    let policies = [
        PolicyKind::MeanCost,
        PolicyKind::GittinsStatic,
        PolicyKind::SageSched,
    ];
    let seeds = ctx.seeds(3);
    // one flat policy x noise x seed queue: per policy, the first seed-chunk
    // is the clean run, the second the noisy one
    let mut cfgs = Vec::new();
    for &policy in &policies {
        for noise in [0.0, 0.2] {
            for &seed in &seeds {
                let mut cfg = base_cfg();
                cfg.policy = policy;
                cfg.workload.rps = 8.0;
                cfg.workload.n_requests = ctx.n_requests(1200);
                cfg.noise_mix = noise;
                cfg.seed = seed;
                cfgs.push(cfg);
            }
        }
    }
    let points = parallel_map(cfgs, |cfg| run_point(&cfg));
    let mut chunks = points.chunks(seeds.len());
    let mut rows = Vec::new();
    for policy in policies {
        let clean = chunks.next().expect("fig11 grid/result mismatch");
        let noisy = chunks.next().expect("fig11 grid/result mismatch");
        let (c, _) = point_means(clean);
        let (n, _) = point_means(noisy);
        println!(
            "| {} | {c:.2} | {n:.2} | {:+.1}% |",
            policy.name(),
            (n - c) / c * 100.0
        );
        rows.push(format!("{},{c:.3},{n:.3}", policy.name()));
    }
    write_csv("fig11", "policy,ttlt_clean,ttlt_noisy", &rows);
}

// ===========================================================================
// Fig 12: cluster-scale overhead
// ===========================================================================
fn fig12(ctx: &Ctx) {
    println!("\n=== fig12: predict+schedule overhead vs cluster size ===");
    let mut cfg = base_cfg();
    if ctx.quick {
        cfg.history_capacity = 2000;
    }
    let mut sim = ClusterSim::new(cfg);
    if ctx.quick {
        sim.samples = 30;
        sim.queue_depth = 200;
    }
    println!("| nodes | aggregate rps | predict (ms) | sched (ms) | total (ms) |");
    println!("|---|---|---|---|---|");
    let mut rows = Vec::new();
    for o in sim.sweep(&[1, 2, 4, 8, 16, 32, 64]) {
        println!(
            "| {} | {:.0} | {:.3} | {:.3} | {:.3} |",
            o.nodes,
            o.aggregate_rps,
            o.predict_latency * 1e3,
            o.sched_latency * 1e3,
            o.total_latency * 1e3
        );
        rows.push(format!(
            "{},{:.0},{:.5},{:.5},{:.5}",
            o.nodes, o.aggregate_rps, o.predict_latency, o.sched_latency, o.total_latency
        ));
    }
    write_csv("fig12", "nodes,rps,predict_s,sched_s,total_s", &rows);
    println!("  (linear growth; negligible vs multi-second TTLTs)");
}

// ===========================================================================
// Fig 12b: event-driven cluster — router A/B on one seeded workload
// ===========================================================================
fn fig12b(ctx: &Ctx) {
    println!("\n=== fig12b: router comparison (event-driven 4-replica cluster) ===");
    let mut cfg = base_cfg();
    cfg.cluster.replicas = 4;
    // heterogeneous fleet: two fast replicas, two at half speed
    cfg.cluster.speeds = vec![1.0, 1.0, 0.5, 0.5];
    cfg.workload.rps = 20.0;
    cfg.workload.n_requests = ctx.n_requests(1200);
    println!("{}", sagesched::metrics::ClusterReport::markdown_header());
    // independent same-config sims, one per router: run them on parallel
    // threads (each is internally deterministic, so the reports — and
    // their printed order below — are unchanged; only wall-clock drops)
    let reports = parallel_map(sagesched::config::RouterKind::ALL.to_vec(), |router| {
        sagesched::cluster::run_router_experiment(&cfg, router)
            .expect("cluster experiment failed")
    });
    let mut rows = Vec::new();
    for r in &reports {
        println!("{}", r.markdown_row());
        rows.push(format!(
            "{},{:.4},{:.4},{:.4},{:.4},{:.3}",
            r.router,
            r.aggregate.ttlt.mean,
            r.aggregate.ttlt.p90,
            r.aggregate.ttft.mean,
            r.aggregate.throughput,
            r.imbalance
        ));
    }
    write_csv(
        "fig12b",
        "router,ttlt_mean,ttlt_p90,ttft_mean,throughput,imbalance",
        &rows,
    );

    // --- burst + failure scenario -----------------------------------------
    // the same fleet under MMPP on/off bursts with one mid-run outage on
    // (fast) replica 0: routers must carry the re-dispatched load on the
    // survivors, and idle replicas may steal queued work during the bursts.
    // Every router must still conserve requests exactly.
    println!("\n--- burst (MMPP) + replica-0 outage ---");
    let mut bcfg = cfg.clone();
    bcfg.workload.arrival.kind = sagesched::config::ArrivalKind::Mmpp;
    bcfg.workload.arrival.burst_factor = 5.0;
    bcfg.workload.arrival.burst_on_mean = 4.0;
    bcfg.workload.arrival.burst_off_mean = 12.0;
    let span = bcfg.workload.n_requests as f64 / bcfg.workload.rps;
    bcfg.cluster.failures = vec![sagesched::config::FailureEvent {
        replica: 0,
        at: span / 3.0,
        duration: span / 6.0,
    }];
    println!("{}", sagesched::metrics::ClusterReport::markdown_header());
    let reports = parallel_map(sagesched::config::RouterKind::ALL.to_vec(), |router| {
        sagesched::cluster::run_router_experiment(&bcfg, router)
            .expect("burst+failure cluster experiment failed")
    });
    let mut rows = Vec::new();
    for r in &reports {
        let n = bcfg.workload.n_requests as u64;
        let accounted = r.aggregate.completed + r.aggregate.rejected + r.aggregate.aborted;
        assert_eq!(accounted, n, "{}: {accounted} accounted of {n}", r.router);
        println!("{}", r.markdown_row());
        rows.push(format!(
            "{},{:.4},{:.4},{:.4},{:.3},{},{},{},{},{:.4}",
            r.router,
            r.aggregate.ttlt.mean,
            r.aggregate.ttlt.p90,
            r.aggregate.throughput,
            r.imbalance,
            r.re_routed,
            r.stolen,
            r.aggregate.rejected,
            r.aggregate.aborted,
            r.aggregate.goodput(),
        ));
    }
    write_csv(
        "fig12b_burst_failure",
        "router,ttlt_mean,ttlt_p90,throughput,imbalance,re_routed,stolen,rejected,aborted,goodput",
        &rows,
    );
    println!("  (outage: replica 0 down {:.0}s..{:.0}s of a ~{span:.0}s trace)",
        span / 3.0, span / 3.0 + span / 6.0);
}

// ===========================================================================
// Fig 12c: elastic autoscaling — static vs reactive vs uncertainty-aware
// ===========================================================================
fn fig12c(ctx: &Ctx) {
    use sagesched::config::{ArrivalKind, AutoscaleKind};
    println!("\n=== fig12c: autoscaling under bursty / diurnal demand ===");
    // one fleet shape for every row: 6 replicas at peak. The static row
    // keeps all 6 for the whole run; the elastic rows may shrink to 2 and
    // grow back to the same peak cap, so goodput per replica-second is the
    // apples-to-apples provisioning-efficiency comparison.
    let peak = 6usize;
    let mut base = base_cfg();
    base.cluster.replicas = peak;
    base.workload.rps = 12.0;
    base.workload.n_requests = ctx.n_requests(1200);
    base.workload.arrival.burst_factor = 6.0;
    base.workload.arrival.burst_on_mean = 4.0;
    base.workload.arrival.burst_off_mean = 12.0;
    base.workload.arrival.diurnal_period = 40.0;
    base.workload.arrival.diurnal_amplitude = 0.8;
    let mut rows = Vec::new();
    for (scenario, kind) in [("mmpp", ArrivalKind::Mmpp), ("diurnal", ArrivalKind::Diurnal)] {
        println!("\n-- {scenario} arrivals --");
        println!(
            "| provisioning | completed | goodput | TTLT mean | TTLT p90 | replica-s | gp/rep-s | scale events |"
        );
        println!("|---|---|---|---|---|---|---|---|");
        for policy in [
            AutoscaleKind::Off,
            AutoscaleKind::Reactive,
            AutoscaleKind::UncertaintyAware,
        ] {
            let mut cfg = base.clone();
            cfg.workload.arrival.kind = kind;
            cfg.cluster.autoscale.kind = policy;
            cfg.cluster.autoscale.min_replicas = 2;
            cfg.cluster.autoscale.max_replicas = peak;
            cfg.cluster.autoscale.provision_delay = 2.0;
            cfg.cluster.autoscale.cooldown = 3.0;
            cfg.cluster.autoscale.interval = 1.0;
            cfg.cluster.autoscale.work_per_replica = 1.0e6;
            let label = match policy {
                AutoscaleKind::Off => "static-6",
                k => k.name(),
            };
            let r = sagesched::cluster::run_router_experiment(&cfg, cfg.cluster.router)
                .expect("autoscale experiment failed");
            let n = cfg.workload.n_requests as u64;
            let accounted =
                r.aggregate.completed + r.aggregate.rejected + r.aggregate.aborted;
            assert_eq!(accounted, n, "{label}: {accounted} accounted of {n}");
            println!(
                "| {label} | {} | {:.3} | {:.2} | {:.2} | {:.0} | {:.3} | {} |",
                r.aggregate.completed,
                r.aggregate.goodput(),
                r.aggregate.ttlt.mean,
                r.aggregate.ttlt.p90,
                r.total_replica_seconds(),
                r.goodput_per_replica_second,
                r.scaling_events.len()
            );
            rows.push(format!(
                "{scenario},{label},{},{:.4},{:.4},{:.4},{:.1},{:.5},{}",
                r.aggregate.completed,
                r.aggregate.goodput(),
                r.aggregate.ttlt.mean,
                r.aggregate.ttlt.p90,
                r.total_replica_seconds(),
                r.goodput_per_replica_second,
                r.scaling_events.len()
            ));
        }
    }
    write_csv(
        "fig12c",
        "scenario,provisioning,completed,goodput,ttlt_mean,ttlt_p90,replica_seconds,goodput_per_replica_second,scale_events",
        &rows,
    );
    println!("  (elastic rows shed trough capacity: same goodput, far fewer replica-seconds)");
}

// ===========================================================================
// Fig 13: sensitivity
// ===========================================================================
fn fig13a(ctx: &Ctx) {
    println!("\n=== fig13a: similarity-threshold sensitivity ===");
    println!("| threshold | TTLT mean |");
    println!("|---|---|");
    let thresholds = [0.6f32, 0.7, 0.8, 0.9, 0.95];
    let seeds = ctx.seeds(3);
    // one flat threshold x seed queue (see fig7)
    let mut cfgs = Vec::new();
    for &th in &thresholds {
        for &seed in &seeds {
            let mut cfg = base_cfg();
            cfg.similarity_threshold = th;
            cfg.workload.rps = 8.0;
            cfg.workload.n_requests = ctx.n_requests(1200);
            cfg.seed = seed;
            cfgs.push(cfg);
        }
    }
    let points = parallel_map(cfgs, |cfg| run_point(&cfg));
    let mut chunks = points.chunks(seeds.len());
    let mut rows = Vec::new();
    for th in thresholds {
        let chunk = chunks.next().expect("fig13a grid/result mismatch");
        let (ttlt, _) = point_means(chunk);
        println!("| {th} | {ttlt:.2} |");
        rows.push(format!("{th},{ttlt:.3}"));
    }
    write_csv("fig13a", "threshold,ttlt_mean", &rows);
}

fn fig13b(ctx: &Ctx) {
    println!("\n=== fig13b: Gittins bucket-size sensitivity ===");
    println!("| bucket (tokens) | TTLT mean |");
    println!("|---|---|");
    let buckets = [25u32, 50, 100, 200, 400, 800];
    let seeds = ctx.seeds(3);
    // one flat bucket x seed queue (see fig7)
    let mut cfgs = Vec::new();
    for &bucket in &buckets {
        for &seed in &seeds {
            let mut cfg = base_cfg();
            cfg.bucket_tokens = bucket;
            cfg.workload.rps = 8.0;
            cfg.workload.n_requests = ctx.n_requests(1200);
            cfg.seed = seed;
            cfgs.push(cfg);
        }
    }
    let points = parallel_map(cfgs, |cfg| run_point(&cfg));
    let mut chunks = points.chunks(seeds.len());
    let mut rows = Vec::new();
    for bucket in buckets {
        let chunk = chunks.next().expect("fig13b grid/result mismatch");
        let (ttlt, _) = point_means(chunk);
        println!("| {bucket} | {ttlt:.2} |");
        rows.push(format!("{bucket},{ttlt:.3}"));
    }
    write_csv("fig13b", "bucket_tokens,ttlt_mean", &rows);
}

// ===========================================================================
// Fig 13c: SLO classes — class-blind vs class-aware serving under bursts
// ===========================================================================
fn fig13c(ctx: &Ctx) {
    use sagesched::config::{ArrivalKind, FailureEvent, RouterKind};
    println!("\n=== fig13c: class-blind vs class-aware serving (MMPP + outage) ===");
    // an overloaded 4-replica cluster under MMPP bursts with a mid-run
    // outage on replica 0 and a finite admission window: exactly the
    // regime where serving every request identically wastes capacity on
    // work nobody is waiting for. Same seeded workload for both rows; the
    // only difference is the class-aware switch.
    let mut base = base_cfg();
    base.cluster.replicas = 4;
    base.workload.rps = 30.0;
    base.workload.n_requests = ctx.n_requests(1200);
    base.workload.arrival.kind = ArrivalKind::Mmpp;
    base.workload.arrival.burst_factor = 5.0;
    base.workload.arrival.burst_on_mean = 4.0;
    base.workload.arrival.burst_off_mean = 12.0;
    base.max_queue = 48;
    let span = base.workload.n_requests as f64 / base.workload.rps;
    base.cluster.failures =
        vec![FailureEvent { replica: 0, at: span / 3.0, duration: span / 6.0 }];
    println!(
        "| serving | goodput | slo-weighted gp | interactive att | standard att \
         | batch att | int TTLT p90 | gp/rep-s | slo-w gp/rep-s |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    for (label, aware) in [("class-blind", false), ("class-aware", true)] {
        let mut cfg = base.clone();
        cfg.slo.class_aware = aware;
        let r = sagesched::cluster::run_router_experiment(&cfg, RouterKind::QuantileCost)
            .expect("slo cluster experiment failed");
        let n = cfg.workload.n_requests as u64;
        let accounted =
            r.aggregate.completed + r.aggregate.rejected + r.aggregate.aborted;
        assert_eq!(accounted, n, "{label}: {accounted} accounted of {n}");
        let att = |class: &str| {
            r.aggregate
                .slo
                .get(class)
                .map(|s| s.attainment())
                .unwrap_or(0.0)
        };
        let int_p90 = r
            .aggregate
            .slo
            .get("interactive")
            .map(|s| s.ttlt.p90)
            .unwrap_or(0.0);
        println!(
            "| {label} | {:.3} | {:.3} | {:.3} | {:.3} | {:.3} | {:.2} | {:.3} | {:.3} |",
            r.aggregate.goodput(),
            r.aggregate.slo_weighted_goodput(),
            att("interactive"),
            att("standard"),
            att("batch"),
            int_p90,
            r.goodput_per_replica_second,
            r.slo_weighted_goodput_per_replica_second,
        );
        rows.push(format!(
            "{label},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{:.5},{:.5}",
            r.aggregate.goodput(),
            r.aggregate.slo_weighted_goodput(),
            att("interactive"),
            att("standard"),
            att("batch"),
            int_p90,
            r.goodput_per_replica_second,
            r.slo_weighted_goodput_per_replica_second,
        ));
    }
    write_csv(
        "fig13c",
        "serving,goodput,slo_weighted_goodput,interactive_attainment,\
         standard_attainment,batch_attainment,interactive_ttlt_p90,\
         goodput_per_replica_second,slo_weighted_goodput_per_replica_second",
        &rows,
    );
    println!("  (class-aware: interactive attainment up, total goodput held)");
}

// ===========================================================================
// Fig 14: correlated failure domains + migration-cost-aware scale-in
// ===========================================================================
fn fig14(ctx: &Ctx) {
    use sagesched::config::{
        ArrivalKind, AutoscaleKind, DomainFailureEvent, FailureDomain, FailureEvent,
        RouterKind, ScaleStep,
    };
    println!("\n=== fig14: correlated failure domains + migration-aware scale-in ===");

    // --- part A: independent vs correlated failures at equal downtime -----
    // the same 4-replica cluster under MMPP bursts loses 3 replica-seconds
    // of capacity two ways: three disjoint 1-replica outages (capacity
    // never below 3/4) vs one rack outage downing all three at once
    // (capacity 1/4, one pooled re-dispatch storm). Same seeded workload;
    // the only difference is the failure *shape*.
    let mut base = base_cfg();
    base.cluster.replicas = 4;
    base.workload.rps = 30.0;
    base.workload.n_requests = ctx.n_requests(1200);
    base.workload.arrival.kind = ArrivalKind::Mmpp;
    base.workload.arrival.burst_factor = 5.0;
    base.workload.arrival.burst_on_mean = 4.0;
    base.workload.arrival.burst_off_mean = 12.0;
    base.slo.class_aware = true;
    let span = base.workload.n_requests as f64 / base.workload.rps;
    let outage = span / 12.0;

    let mut independent = base.clone();
    independent.cluster.failures = vec![
        FailureEvent { replica: 1, at: span / 4.0, duration: outage },
        FailureEvent { replica: 2, at: span / 2.0, duration: outage },
        FailureEvent { replica: 3, at: 3.0 * span / 4.0, duration: outage },
    ];
    let mut correlated = base.clone();
    correlated.cluster.failure_domains = vec![FailureDomain {
        name: "rack0".to_string(),
        replicas: vec![1, 2, 3],
    }];
    correlated.cluster.domain_failures =
        vec![DomainFailureEvent { domain: 0, at: span / 2.0, duration: outage }];

    println!(
        "| failure shape | goodput | interactive att | int TTLT p90 | re-routed \
         | slo-w gp/rep-s |"
    );
    println!("|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    let mut atts = Vec::new();
    for (label, cfg) in [("independent", &independent), ("correlated", &correlated)] {
        let r = sagesched::cluster::run_router_experiment(cfg, RouterKind::QuantileCost)
            .expect("fig14 failure-shape experiment failed");
        let n = cfg.workload.n_requests as u64;
        let accounted =
            r.aggregate.completed + r.aggregate.rejected + r.aggregate.aborted;
        assert_eq!(accounted, n, "{label}: {accounted} accounted of {n}");
        let att = r
            .aggregate
            .slo
            .get("interactive")
            .map(|s| s.attainment())
            .unwrap_or(0.0);
        let p90 = r
            .aggregate
            .slo
            .get("interactive")
            .map(|s| s.ttlt.p90)
            .unwrap_or(0.0);
        println!(
            "| {label} | {:.3} | {:.3} | {:.2} | {} | {:.3} |",
            r.aggregate.goodput(),
            att,
            p90,
            r.re_routed,
            r.slo_weighted_goodput_per_replica_second,
        );
        rows.push(format!(
            "{label},{:.4},{:.4},{:.4},{},{:.5}",
            r.aggregate.goodput(),
            att,
            p90,
            r.re_routed,
            r.slo_weighted_goodput_per_replica_second,
        ));
        atts.push(att);
    }
    write_csv(
        "fig14_failure_shape",
        "shape,goodput,interactive_attainment,interactive_ttlt_p90,re_routed,\
         slo_weighted_goodput_per_replica_second",
        &rows,
    );
    println!(
        "  (equal downtime, different shape: correlated {:.3} vs independent \
         {:.3} interactive attainment)",
        atts[1], atts[0]
    );

    // --- part B: drain-only vs migration-cost-aware scale-in --------------
    // a heterogeneous fleet (one replica at 0.3x speed) scales 3 -> 2
    // mid-run. Drain-only waits out the victim's partially-generated work;
    // migration-aware scale-in ships it to the survivors when the KV
    // transfer is predicted cheaper, retiring the victim earlier at equal
    // completions.
    let mut sbase = base_cfg();
    sbase.cluster.replicas = 3;
    sbase.cluster.speeds = vec![1.0, 1.0, 0.3];
    sbase.workload.rps = 24.0;
    sbase.workload.n_requests = ctx.n_requests(960);
    let step_at = sbase.workload.n_requests as f64 / sbase.workload.rps / 2.0;
    sbase.cluster.autoscale.kind = AutoscaleKind::Step;
    sbase.cluster.autoscale.steps = vec![ScaleStep { at: step_at, target: 2 }];
    sbase.cluster.autoscale.interval = 1.0;

    let mut mig = sbase.clone();
    mig.cluster.migration_kv_per_token = 0.05;
    mig.cluster.migration_quantile = 0.9;

    println!("\n| scale-in | completed | migrated | replica-s | gp/rep-s | TTLT p90 |");
    println!("|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    let mut gps = Vec::new();
    for (label, cfg) in [("drain-only", &sbase), ("migration-aware", &mig)] {
        let r = sagesched::cluster::run_router_experiment(cfg, RouterKind::CostAware)
            .expect("fig14 scale-in experiment failed");
        let n = cfg.workload.n_requests as u64;
        let accounted =
            r.aggregate.completed + r.aggregate.rejected + r.aggregate.aborted;
        assert_eq!(accounted, n, "{label}: {accounted} accounted of {n}");
        println!(
            "| {label} | {} | {} | {:.0} | {:.4} | {:.2} |",
            r.aggregate.completed,
            r.migrated,
            r.total_replica_seconds(),
            r.goodput_per_replica_second,
            r.aggregate.ttlt.p90,
        );
        rows.push(format!(
            "{label},{},{},{:.2},{:.5},{:.4}",
            r.aggregate.completed,
            r.migrated,
            r.total_replica_seconds(),
            r.goodput_per_replica_second,
            r.aggregate.ttlt.p90,
        ));
        gps.push(r.goodput_per_replica_second);
    }
    write_csv(
        "fig14_scale_in",
        "scale_in,completed,migrated,replica_seconds,goodput_per_replica_second,\
         ttlt_p90",
        &rows,
    );
    println!(
        "  (migration-aware {:.4} vs drain-only {:.4} goodput/replica-second)",
        gps[1], gps[0]
    );
}

// ===========================================================================
// Fig 15: predictor goodput + rank quality under mid-run workload drift
// ===========================================================================
fn fig15(ctx: &Ctx) {
    println!("\n=== fig15: predictors under workload drift (SageSched policy) ===");
    // Overloaded single replica with a queue timeout, so scheduling order
    // decides goodput. Two runs per predictor on the same seeded trace:
    // drift off ("steady") and a topic->length remap at the halfway point
    // ("drift"); both reports trim the first half, so the drifted run's
    // numbers are entirely post-shift. The windowed Kendall tau is taken
    // over the final completions of each run.
    println!("| predictor | goodput steady | goodput post-drift | tau steady | tau post-drift |");
    println!("|---|---|---|---|---|");
    let preds =
        [PredictorKind::History, PredictorKind::Ranking, PredictorKind::Oracle];
    let seeds = ctx.seeds(2);
    // one flat predictor x drift x seed queue; per predictor, the first
    // seed-chunk is the steady run, the second the drifted one
    let mut cfgs = Vec::new();
    for &pred in &preds {
        for drift in [0.0, 0.5] {
            for &seed in &seeds {
                let mut cfg = base_cfg();
                cfg.policy = PolicyKind::SageSched;
                cfg.predictor = pred;
                cfg.workload.rps = 14.0;
                cfg.workload.n_requests = ctx.n_requests(1600);
                cfg.workload.drift.at_fraction = drift;
                cfg.request_timeout = 25.0;
                cfg.warmup_fraction = 0.5;
                cfg.seed = seed;
                cfgs.push(cfg);
            }
        }
    }
    let points = parallel_map(cfgs, |cfg| {
        let r = run_experiment(&cfg).expect("fig15 experiment failed");
        (r.goodput(), r.pred_tau, r.pred_tau_n as f64)
    });
    let mut chunks = points.chunks(seeds.len());
    let mut rows = Vec::new();
    for pred in preds {
        let mut gp = [0.0f64; 2];
        let mut tau = [0.0f64; 2];
        let mut tau_n = [0u64; 2];
        for i in 0..2 {
            let chunk = chunks.next().expect("fig15 grid/result mismatch");
            let gps: Vec<f64> = chunk.iter().map(|p| p.0).collect();
            let taus: Vec<f64> = chunk.iter().map(|p| p.1).collect();
            let ns: Vec<f64> = chunk.iter().map(|p| p.2).collect();
            gp[i] = mean(&gps);
            tau[i] = mean(&taus);
            tau_n[i] = mean(&ns) as u64;
        }
        println!(
            "| {} | {:.3} | {:.3} | {:.3} ({}) | {:.3} ({}) |",
            pred.name(),
            gp[0],
            gp[1],
            tau[0],
            tau_n[0],
            tau[1],
            tau_n[1],
        );
        rows.push(format!(
            "{},{:.4},{:.4},{:.4},{:.4}",
            pred.name(),
            gp[0],
            gp[1],
            tau[0],
            tau[1],
        ));
    }
    write_csv(
        "fig15",
        "predictor,goodput_steady,goodput_drift,tau_steady,tau_drift",
        &rows,
    );
    println!(
        "  (drift poisons the history window's retrieved lengths; the online \
         ranker re-learns the ordering and the oracle bounds both)"
    );
}

// ===========================================================================
// Fig 1a on the real engine (optional extended check)
// ===========================================================================
fn fig1a_real(ctx: &Ctx) {
    if !sagesched::runtime::Runtime::artifacts_present("artifacts") {
        println!("\n=== fig1a_real: skipped (run `make artifacts` first) ===");
        return;
    }
    println!("\n=== fig1a_real: stochastic lengths from the real tiny LM ===");
    use sagesched::engine::RealEngine;
    let rt = sagesched::runtime::Runtime::load("artifacts").unwrap();
    let mut eng = RealEngine::new(rt, 3);
    let prompts = [
        "tell me about glaciers",
        "write a story",
        "summarize: the quick brown fox jumps over the lazy dog",
    ];
    let trials = if ctx.quick { 8 } else { 24 };
    println!("| prompt | trials | min | median | max |");
    println!("|---|---|---|---|---|");
    let mut rows = Vec::new();
    for (pi, prompt) in prompts.iter().enumerate() {
        let mut lens = Vec::new();
        for t in 0..trials {
            let req = sagesched::core::Request {
                id: (pi * 1000 + t) as u64,
                prompt: prompt.to_string(),
                input_len: prompt.len() as u32 + 1,
                true_output_len: u32::MAX,
                arrival: 0.0,
                dataset: DatasetKind::ShareGpt,
                topic: 0,
                embedding: sagesched::embedding::Embedding::normalize(vec![1.0; 4]),
                true_dist: None,
                slo: sagesched::slo::SloClass::Standard,
                prefix_key: Vec::new(),
            };
            let pr = eng.prefill(&req).unwrap();
            let mut generated = 1u32;
            if !pr.finished {
                let mut lanes = vec![LaneState::new(&req, 1)];
                while !lanes[0].finished && lanes[0].generated < 180 {
                    eng.decode_step(&mut lanes, 0).unwrap();
                }
                generated = lanes[0].generated;
            }
            eng.evict(req.id);
            lens.push(generated as f64);
        }
        lens.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "| {pi} | {trials} | {:.0} | {:.0} | {:.0} |",
            lens[0],
            lens[lens.len() / 2],
            lens[lens.len() - 1]
        );
        rows.push(format!(
            "{pi},{trials},{},{},{}",
            lens[0],
            lens[lens.len() / 2],
            lens[lens.len() - 1]
        ));
    }
    write_csv("fig1a_real", "prompt,trials,min,median,max", &rows);
}

// ===========================================================================
// Fig 16: session workloads — cache-affinity routing vs least-loaded as the
// share of session (shared-prefix) traffic rises
// ===========================================================================
fn fig16(ctx: &Ctx) {
    use sagesched::config::RouterKind;
    println!("\n=== fig16: shared-prefix sessions + cache-affinity routing ===");
    // Multi-turn sessions over a large shared system prompt: every turn
    // re-submits the conversation, so a router that lands a session's turns
    // on the replica already holding its prefix blocks skips most of the
    // prefill. Sweep the fraction of arrivals that start sessions and
    // compare session-blind least-loaded against the cache-affinity router
    // on the same seeded workload.
    let mut base = base_cfg();
    base.cluster.replicas = 3;
    base.workload.rps = 24.0;
    base.workload.n_requests = ctx.n_requests(900);
    base.slo.class_aware = true;
    base.workload.sessions.enabled = true;
    base.workload.sessions.system_prompt_tokens = 800;
    base.workload.sessions.turns_mean = 5.0;
    base.workload.sessions.think_mean = 3.0;
    println!(
        "| prefix share | router | int TTFT mean | int TTFT p90 | hit rate | \
         prefill tokens saved | TTLT mean |"
    );
    println!("|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    for share in [0.0, 0.3, 0.6, 0.9] {
        let mut cfg = base.clone();
        cfg.workload.sessions.prefix_share = share;
        for router in [RouterKind::LeastLoaded, RouterKind::CacheAffinity] {
            let r = sagesched::cluster::run_router_experiment(&cfg, router)
                .expect("fig16 session experiment failed");
            let (ttft_mean, ttft_p90) = r
                .aggregate
                .slo
                .get("interactive")
                .map(|s| (s.ttft.mean, s.ttft.p90))
                .unwrap_or((0.0, 0.0));
            println!(
                "| {share:.1} | {} | {:.3} | {:.3} | {:.3} | {} | {:.3} |",
                router.name(),
                ttft_mean,
                ttft_p90,
                r.aggregate.kv_prefix_hit_rate(),
                r.aggregate.kv_prefill_tokens_saved,
                r.aggregate.ttlt.mean,
            );
            rows.push(format!(
                "{share},{},{:.5},{:.5},{:.5},{},{:.5}",
                router.name(),
                ttft_mean,
                ttft_p90,
                r.aggregate.kv_prefix_hit_rate(),
                r.aggregate.kv_prefill_tokens_saved,
                r.aggregate.ttlt.mean,
            ));
        }
    }
    write_csv(
        "fig16",
        "prefix_share,router,interactive_ttft_mean,interactive_ttft_p90,\
         prefix_hit_rate,prefill_tokens_saved,ttlt_mean",
        &rows,
    );
    println!(
        "  (rising prefix share: hit rate and tokens saved climb, and the \
         cache-affinity router's warm placements cut interactive TTFT)"
    );
}

// ===========================================================================
// Fig 17: colocated vs disaggregated prefill/decode at equal hardware
// ===========================================================================
fn fig17(ctx: &Ctx) {
    use sagesched::config::{PoolRole, RouterKind};
    use sagesched::slo::SloClass;
    println!("\n=== fig17: colocated vs disaggregated pools (equal hardware) ===");
    // Four replicas either serve everything (colocated) or split 2+2 into
    // a prefill pool and a decode pool behind the KV-transfer fabric. Same
    // seeded workload per SLO mix; the disaggregated rows pay the fabric
    // hop but keep long decode batches from sitting in front of fresh
    // prompts' prefill — the interactive TTFT-attainment column is where
    // that shows up.
    let mut base = base_cfg();
    base.cluster.replicas = 4;
    base.workload.rps = 24.0;
    base.workload.n_requests = ctx.n_requests(1200);
    base.slo.class_aware = true;
    let mixes: [(&str, Vec<(SloClass, f64)>); 3] = [
        (
            "interactive-heavy",
            vec![
                (SloClass::Interactive, 0.6),
                (SloClass::Standard, 0.3),
                (SloClass::Batch, 0.1),
            ],
        ),
        (
            "balanced",
            vec![
                (SloClass::Interactive, 0.25),
                (SloClass::Standard, 0.5),
                (SloClass::Batch, 0.25),
            ],
        ),
        (
            "batch-heavy",
            vec![
                (SloClass::Interactive, 0.1),
                (SloClass::Standard, 0.3),
                (SloClass::Batch, 0.6),
            ],
        ),
    ];
    println!(
        "| slo mix | serving | int TTFT att | int TTFT p90 | goodput | \
         fabric util | prefill/decode rep-s |"
    );
    println!("|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    for (mix_name, mix) in &mixes {
        let mut cfg = base.clone();
        cfg.workload.slo_mix = mix.clone();
        for disagg in [false, true] {
            let mut cfg = cfg.clone();
            let label = if disagg {
                cfg.cluster.pools = vec![
                    PoolRole::Prefill,
                    PoolRole::Prefill,
                    PoolRole::Decode,
                    PoolRole::Decode,
                ];
                "disaggregated 2+2"
            } else {
                "colocated 4"
            };
            let r = sagesched::cluster::run_router_experiment(&cfg, RouterKind::QuantileCost)
                .expect("fig17 experiment failed");
            let n = cfg.workload.n_requests as u64;
            let accounted =
                r.aggregate.completed + r.aggregate.rejected + r.aggregate.aborted;
            assert_eq!(accounted, n, "{mix_name}/{label}: lost requests");
            let (ttft_att, ttft_p90) = r
                .aggregate
                .slo
                .get("interactive")
                .map(|s| (s.ttft_attainment(), s.ttft.p90))
                .unwrap_or((0.0, 0.0));
            let pools = if r.pool_replica_seconds.len() == 2 {
                format!(
                    "{:.0}/{:.0}",
                    r.pool_replica_seconds[0], r.pool_replica_seconds[1]
                )
            } else {
                "-".to_string()
            };
            println!(
                "| {mix_name} | {label} | {:.3} | {:.3} | {:.3} | {:.3} | {pools} |",
                ttft_att,
                ttft_p90,
                r.aggregate.goodput(),
                r.transfer_utilization,
            );
            rows.push(format!(
                "{mix_name},{label},{:.5},{:.5},{:.5},{:.5},{},{}",
                ttft_att,
                ttft_p90,
                r.aggregate.goodput(),
                r.transfer_utilization,
                r.transfers,
                pools,
            ));
        }
    }
    write_csv(
        "fig17",
        "slo_mix,serving,interactive_ttft_attainment,interactive_ttft_p90,\
         goodput,transfer_utilization,transfers,pool_replica_seconds",
        &rows,
    );
    println!(
        "  (dedicated prefill capacity: interactive TTFT attainment up under \
         interactive-heavy mixes at equal total hardware)"
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick =
        args.iter().any(|a| a == "--quick") || std::env::var("FIGURES_QUICK").is_ok();
    let ctx = Ctx { quick };
    let wanted: Vec<&str> = args
        .iter()
        .filter(|a| !a.starts_with("--") && a.as_str() != "--bench")
        .map(String::as_str)
        .collect();
    let all: Vec<(&str, fn(&Ctx))> = vec![
        ("fig1a", fig1a),
        ("fig1a_real", fig1a_real),
        ("fig1b", fig1b),
        ("fig2a", fig2a),
        ("fig2b", fig2b),
        ("fig4", fig4),
        ("fig5a", fig5a),
        ("fig5b", fig5b),
        ("fig6", fig6),
        ("fig7", fig7),
        ("fig8", fig8),
        ("fig9", fig9),
        ("fig10", fig10),
        ("fig11", fig11),
        ("fig12", fig12),
        ("fig12b", fig12b),
        ("fig12c", fig12c),
        ("fig13a", fig13a),
        ("fig13b", fig13b),
        ("fig13c", fig13c),
        ("fig14", fig14),
        ("fig15", fig15),
        ("fig16", fig16),
        ("fig17", fig17),
    ];
    let t0 = std::time::Instant::now();
    for (name, f) in &all {
        if wanted.is_empty() || wanted.iter().any(|w| w == name) {
            f(&ctx);
        }
    }
    println!("\nall figures done in {:.1}s", t0.elapsed().as_secs_f64());
}
